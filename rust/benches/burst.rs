//! `cargo bench --bench burst` — regenerates the burst-robustness extension
//! table end-to-end.

use blackbox_sched::bench::Suite;
use blackbox_sched::experiments::{self, ExpOpts};

fn main() {
    let mut suite = Suite::new("burst");
    let opts = ExpOpts {
        seeds: std::env::var("BENCH_SEEDS").ok().and_then(|s| s.parse().ok()).unwrap_or(5),
        out_dir: "target/bench-results/tables".to_string(),
        ..ExpOpts::default()
    };
    suite.bench_n("burst (full experiment)", 3, || {
        experiments::run_experiment("burst", &opts).expect("experiment failed");
    });
    suite.finish();
}
