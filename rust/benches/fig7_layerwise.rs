//! `cargo bench --bench fig7_layerwise` — regenerates Figure 7 (layerwise progression)
//! end-to-end and reports the wall-clock cost of the experiment.

use blackbox_sched::bench::Suite;
use blackbox_sched::experiments::{self, ExpOpts};

fn main() {
    let mut suite = Suite::new("fig7_layerwise");
    let opts = ExpOpts {
        seeds: std::env::var("BENCH_SEEDS").ok().and_then(|s| s.parse().ok()).unwrap_or(5),
        out_dir: "target/bench-results/tables".to_string(),
        ..ExpOpts::default()
    };
    suite.bench_n("fig7_layerwise (full experiment)", 3, || {
        experiments::run_experiment("layerwise", &opts).expect("experiment failed");
    });
    suite.finish();
}
