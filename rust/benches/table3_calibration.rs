//! `cargo bench --bench table3_calibration` — regenerates Table 3 (latency calibration)
//! end-to-end and reports the wall-clock cost of the experiment.

use blackbox_sched::bench::Suite;
use blackbox_sched::experiments::{self, ExpOpts};

fn main() {
    let mut suite = Suite::new("table3_calibration");
    let opts = ExpOpts {
        seeds: std::env::var("BENCH_SEEDS").ok().and_then(|s| s.parse().ok()).unwrap_or(5),
        out_dir: "target/bench-results/tables".to_string(),
        ..ExpOpts::default()
    };
    suite.bench_n("table3_calibration (full experiment)", 3, || {
        experiments::run_experiment("calibration", &opts).expect("experiment failed");
    });
    suite.finish();
}
