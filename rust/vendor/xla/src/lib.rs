//! Vendored **API stub** for the `xla` PJRT bindings.
//!
//! The offline build image does not ship the real PJRT bindings (a native
//! dependency on `xla_extension`), but the `pjrt` feature of
//! `blackbox-sched` must still *build* so CI can compile and type-check the
//! runtime path and run the (artifact-gated) integration tests. This crate
//! vendors exactly the API surface `runtime::pjrt_impl` consumes:
//!
//! * [`PjRtClient::cpu`] / [`PjRtClient::compile`]
//! * [`HloModuleProto::from_text_file`] / [`XlaComputation::from_proto`]
//! * [`PjRtLoadedExecutable::execute`] / [`PjRtBuffer::to_literal_sync`]
//! * [`Literal`] construction, reshape, tuple unpacking, and extraction
//!
//! Pure data plumbing ([`Literal::vec1`], [`Literal::reshape`],
//! [`Literal::to_vec`]) is implemented for real so unit tests can exercise
//! it; anything that needs an actual XLA runtime ([`PjRtClient::cpu`] first
//! of all) fails with an actionable [`Error`] naming this stub. Swapping in
//! the real bindings is a one-line `Cargo.toml` change — no source edits —
//! because the signatures match the upstream `xla` crate.

use std::fmt;

/// Stub error: carries a message explaining what needs the real bindings.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Result alias matching the upstream crate's fallible API.
pub type Result<T> = std::result::Result<T, Error>;

fn stub_unavailable(what: &str) -> Error {
    Error(format!(
        "vendored xla stub: {what} requires the real PJRT bindings \
         (xla_extension); this build vendors only the API surface so \
         `--features pjrt` compiles offline"
    ))
}

/// Parsed HLO module. The stub keeps the text so artifact plumbing (paths,
/// readability, metadata checks) is exercised for real.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    /// Read an HLO text file from disk. I/O errors are reported for real;
    /// no parsing happens (the stub cannot execute HLO anyway).
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }

    /// Raw HLO text length, in bytes (introspection/testing only).
    pub fn text_len(&self) -> usize {
        self.text.len()
    }
}

/// A computation handle built from a parsed module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client handle. The stub cannot create one: real execution needs the
/// native bindings, and failing here (the first runtime call) gives callers
/// one clean degradation point.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(stub_unavailable("creating a PJRT CPU client"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(stub_unavailable("compiling an executable"))
    }
}

/// Compiled executable handle (never constructed by the stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: AsRef<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_unavailable("executing a compiled module"))
    }
}

/// Device buffer handle (never constructed by the stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(stub_unavailable("transferring a device buffer"))
    }
}

/// Element types extractable from a [`Literal`] via [`Literal::to_vec`].
pub trait NativeType: Sized {
    fn from_literal(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn from_literal(lit: &Literal) -> Result<Vec<f32>> {
        Ok(lit.data.clone())
    }
}

/// Host-side typed array. Construction and reshape work for real (they are
/// pure data plumbing); tuple unpacking exists only on executor results,
/// which the stub never produces.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1(xs: &[f32]) -> Literal {
        Literal { data: xs.to_vec(), dims: vec![xs.len() as i64] }
    }

    /// Reinterpret with new dimensions; the element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n < 0 || n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape to {dims:?} ({n} elements) mismatches literal of {} elements",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Dimensions of this literal.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Unpack a 1-tuple result literal. Only executor results are tuples,
    /// and the stub never produces one.
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(stub_unavailable("unpacking a tuple literal"))
    }

    /// Extract the host data as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::from_literal(self)
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_fails_with_actionable_message() {
        let err = PjRtClient::cpu().err().expect("stub cannot create clients");
        let msg = err.to_string();
        assert!(msg.contains("vendored xla stub"), "{msg}");
        assert!(msg.contains("--features pjrt"), "{msg}");
    }

    #[test]
    fn literal_plumbing_works_for_real() {
        let lit = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(lit.dims(), &[6]);
        let m = lit.reshape(&[2, 3]).expect("6 elements reshape to 2x3");
        assert_eq!(m.dims(), &[2, 3]);
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(lit.reshape(&[4, 2]).is_err(), "element-count mismatch must fail");
    }

    #[test]
    fn hlo_text_file_io_is_real() {
        let path = std::env::temp_dir().join("xla_stub_test.hlo.txt");
        std::fs::write(&path, "HloModule stub_test\n").unwrap();
        let proto = HloModuleProto::from_text_file(path.to_str().unwrap()).unwrap();
        assert!(proto.text_len() > 0);
        let _ = XlaComputation::from_proto(&proto);
        let _ = std::fs::remove_file(&path);
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo.txt").is_err());
    }

    #[test]
    fn executable_surface_errors_not_panics() {
        let exe = PjRtLoadedExecutable;
        assert!(exe.execute(&[Literal::vec1(&[0.0])]).is_err());
        assert!(PjRtBuffer.to_literal_sync().is_err());
        assert!(Literal::vec1(&[0.0]).to_tuple1().is_err());
    }
}
