//! Minimal, vendored stand-in for the `anyhow` crate.
//!
//! The offline build image has no crates.io access, so the workspace ships
//! the small slice of anyhow's API this codebase actually uses as a path
//! dependency: [`Error`], [`Result`], the [`Context`] extension trait, and
//! the [`bail!`]/[`anyhow!`]/[`ensure!`] macros.
//!
//! Differences from upstream anyhow, chosen for a dependency-free build:
//! * `Error` is a plain `Box<dyn std::error::Error + Send + Sync>` type
//!   alias, so every `?` conversion rides the std `From` impls (any
//!   `std::error::Error + Send + Sync` type, plus `String`/`&str`).
//! * Context frames are [`ContextError`] wrappers; normal `Display` prints
//!   the outermost message and alternate (`{:#}`) formatting prints the
//!   full `outer: inner: …` chain, matching upstream's report style.
//! * No backtrace capture and no downcasting helpers (unused here).

use std::error::Error as StdError;
use std::fmt;

/// Boxed dynamic error. Any `std::error::Error + Send + Sync` value
/// converts into it via `?`; strings convert via the std `From` impls.
pub type Error = Box<dyn StdError + Send + Sync + 'static>;

/// `Result` with a boxed-error default, mirroring `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// One context frame stacked on top of an underlying cause.
#[derive(Debug)]
pub struct ContextError {
    context: String,
    source: Error,
}

impl fmt::Display for ContextError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}: {:#}", self.context, self.source)
        } else {
            write!(f, "{}", self.context)
        }
    }
}

impl StdError for ContextError {
    fn source(&self) -> Option<&(dyn StdError + 'static)> {
        Some(&*self.source)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`, like `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap the error (or `None`) with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            Box::new(ContextError { context: context.to_string(), source: e.into() }) as Error
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            Box::new(ContextError { context: f().to_string(), source: e.into() }) as Error
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::from(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::from(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::from(::std::format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    fn fails() -> Result<()> {
        bail!("broke at step {}", 3)
    }

    #[test]
    fn bail_formats_message() {
        let err = fails().unwrap_err();
        assert_eq!(err.to_string(), "broke at step 3");
        assert_eq!(format!("{err:#}"), "broke at step 3");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err::<(), std::io::Error>(io_err())?;
            Ok(())
        }
        let err = inner().unwrap_err();
        assert!(err.to_string().contains("gone"));
    }

    #[test]
    fn context_chains_under_alternate_formatting() {
        let err: Error = Err::<(), _>(io_err()).context("reading meta").unwrap_err();
        assert_eq!(err.to_string(), "reading meta");
        let full = format!("{err:#}");
        assert!(full.starts_with("reading meta: "), "{full}");
        assert!(full.contains("gone"), "{full}");
        // Double-wrapped context keeps the whole chain visible.
        let err2: Error = Err::<(), _>(err).with_context(|| "loading predictor").unwrap_err();
        let full2 = format!("{err2:#}");
        assert!(full2.starts_with("loading predictor: reading meta: "), "{full2}");
    }

    #[test]
    fn option_context() {
        let err = None::<u32>.context("missing value").unwrap_err();
        assert_eq!(err.to_string(), "missing value");
        let ok = Some(7u32).with_context(|| "unused").unwrap();
        assert_eq!(ok, 7);
    }

    #[test]
    fn ensure_returns_on_false() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert_eq!(check(2).unwrap(), 2);
        assert!(check(-1).unwrap_err().to_string().contains("-1"));
    }

    #[test]
    fn source_chain_is_walkable() {
        let err: Error = Err::<(), _>(io_err()).context("outer").unwrap_err();
        let src = err.source().expect("context keeps the cause");
        assert!(src.to_string().contains("gone"));
    }
}
