//! Property tests for the sharded provider pool.
//!
//! The load-bearing contract: a 1-shard [`ProviderPool`] is **bit-identical**
//! to a bare [`MockProvider`] on arbitrary submit/finish sequences — same
//! `Started` events (jitter bits included), same promotions, same
//! introspection counters. Every pre-pool experiment CSV rests on this.

use blackbox_sched::provider::fault::FaultPlan;
use blackbox_sched::provider::pool::{PoolCfg, ProviderPool};
use blackbox_sched::provider::{MockProvider, ProviderCfg};
use blackbox_sched::testing::prop;
use blackbox_sched::util::rng::Rng;

#[test]
fn one_shard_pool_is_bit_identical_to_bare_provider() {
    prop::forall(60, |g| {
        let cfg = ProviderCfg {
            base_ms: g.f64_in(50.0, 500.0),
            per_token_ms: g.f64_in(0.1, 5.0),
            max_concurrency: g.usize_in(1, 8),
            slowdown_gamma: g.f64_in(0.0, 2.0),
            slowdown_exp: g.f64_in(0.5, 2.0),
            slowdown_ref: g.f64_in(1.0, 10.0),
            jitter_sigma: if g.bool() { g.f64_in(0.01, 0.3) } else { 0.0 },
        };
        let seed = g.u64();
        let rng = Rng::new(seed).derive("provider");
        let mut bare = MockProvider::new(cfg.clone(), rng.clone());
        let mut pool = ProviderPool::new(&PoolCfg::single(cfg), rng);

        // Requests currently *running* (finish is only legal for these —
        // the DES only ever fires ProviderDone for started requests).
        let mut started_ids: Vec<usize> = Vec::new();
        let mut next_id = 0usize;
        let mut now = 0.0f64;
        let n_ops = g.usize_in(1, 120);
        for _ in 0..n_ops {
            now += g.f64_in(0.0, 50.0);
            if started_ids.is_empty() || g.bool() {
                let tokens = g.f64_in(1.0, 2000.0);
                let a = bare.submit(next_id, tokens, now);
                let b = pool.submit(next_id, tokens, 0, now);
                assert_eq!(a, b, "submit diverged at id {next_id}");
                if let Some(s) = a {
                    assert_eq!(s.id, next_id);
                    started_ids.push(s.id);
                }
                next_id += 1;
            } else {
                let pick = g.usize_in(0, started_ids.len());
                let id = started_ids.swap_remove(pick);
                let a = bare.on_finish(now);
                let b = pool.on_finish(id, now);
                assert_eq!(a, b, "promotions diverged finishing {id}");
                for s in &a {
                    started_ids.push(s.id);
                }
            }
            assert_eq!(bare.running(), pool.total_running());
            assert_eq!(bare.hidden_queue_len(), pool.hidden_queue_len());
        }
        assert_eq!(bare.peak_hidden_queue(), pool.peak_hidden_queue());
        assert_eq!(bare.total_started(), pool.total_started());
    });
}

#[test]
fn multi_shard_pool_conserves_every_request() {
    prop::forall(40, |g| {
        let n_shards = g.usize_in(2, 5);
        let cfg = ProviderCfg {
            max_concurrency: g.usize_in(1, 4),
            jitter_sigma: 0.05,
            ..ProviderCfg::default()
        };
        let pool_cfg = PoolCfg { shards: vec![cfg; n_shards], faults: FaultPlan::default() };
        let mut pool = ProviderPool::new(&pool_cfg, Rng::new(g.u64()));

        let n = g.usize_in(1, 60);
        let mut started_ids: Vec<usize> = Vec::new();
        for id in 0..n {
            let shard = g.usize_in(0, n_shards);
            if let Some(s) = pool.submit(id, g.f64_in(10.0, 3000.0), shard, 0.0) {
                started_ids.push(s.id);
            }
        }
        // Finish everything in arbitrary order; promotions keep the fleet
        // flowing until every submitted request has run.
        let mut finished = 0usize;
        while let Some(pos) = (!started_ids.is_empty()).then(|| g.usize_in(0, started_ids.len())) {
            let id = started_ids.swap_remove(pos);
            finished += 1;
            for s in pool.on_finish(id, finished as f64) {
                started_ids.push(s.id);
            }
        }
        assert_eq!(finished, n, "every submitted request eventually runs and finishes");
        assert_eq!(pool.total_started(), n as u64);
        assert_eq!(pool.total_running(), 0);
        assert_eq!(pool.hidden_queue_len(), 0);
        assert_eq!(pool.started_by_shard().iter().sum::<u64>(), n as u64);
    });
}

/// Draw a random *extension-only* fault plan: per shard, a handful of
/// non-overlapping windows, each a blackout or a slow-down brownout
/// (factor ≤ 1). These are the plans the partitioned loop accepts.
fn random_extension_only_plan(g: &mut prop::Gen, n_shards: usize) -> FaultPlan {
    let mut plan = FaultPlan::default();
    for shard in 0..n_shards {
        let mut t = g.f64_in(0.0, 500.0);
        for _ in 0..g.usize_in(0, 3) {
            let t0 = t + g.f64_in(1.0, 300.0);
            let t1 = t0 + g.f64_in(1.0, 800.0);
            plan = if g.bool() {
                plan.blackout(shard, t0, t1).unwrap()
            } else {
                plan.brownout(shard, t0, t1, g.f64_in(0.05, 1.0)).unwrap()
            };
            t = t1;
        }
    }
    plan
}

#[test]
fn untouched_shards_are_bit_identical_under_a_fault_plan() {
    // A plan whose windows all live on the last shard must leave every
    // other shard's events byte-identical to the fault-free pool — the
    // same no-float-ops contract an empty plan gives the whole fleet.
    prop::forall(40, |g| {
        let n_shards = g.usize_in(2, 5);
        let cfg = ProviderCfg {
            max_concurrency: g.usize_in(1, 4),
            jitter_sigma: 0.1,
            ..ProviderCfg::default()
        };
        let seed = g.u64();
        let faulted_shard = n_shards - 1;
        let plan = FaultPlan::default()
            .blackout(faulted_shard, 0.0, g.f64_in(100.0, 5_000.0))
            .unwrap();
        let clean_cfg =
            PoolCfg { shards: vec![cfg.clone(); n_shards], faults: FaultPlan::default() };
        let fault_cfg = PoolCfg { shards: vec![cfg; n_shards], faults: plan };
        let mut clean = ProviderPool::new(&clean_cfg, Rng::new(seed));
        let mut faulted = ProviderPool::new(&fault_cfg, Rng::new(seed));

        // Traffic only ever touches shards 0..faulted_shard.
        let mut now = 0.0f64;
        let mut started: Vec<(usize, f64)> = Vec::new();
        let mut next_id = 0usize;
        for _ in 0..g.usize_in(1, 80) {
            now += g.f64_in(0.0, 40.0);
            if started.is_empty() || g.bool() {
                let shard = g.usize_in(0, faulted_shard);
                let tokens = g.f64_in(1.0, 2000.0);
                let a = clean.submit(next_id, tokens, shard, now);
                let b = faulted.submit(next_id, tokens, shard, now);
                assert_eq!(a, b, "untouched shard diverged at id {next_id}");
                if let Some(s) = a {
                    started.push((s.id, s.finish_ms));
                }
                next_id += 1;
            } else {
                let (id, t) = started.swap_remove(g.usize_in(0, started.len()));
                let a = clean.on_finish(id, t);
                let b = faulted.on_finish(id, t);
                assert_eq!(a, b, "promotions diverged finishing {id}");
                for s in &a {
                    started.push((s.id, s.finish_ms));
                }
            }
        }
        assert_eq!(faulted.faulted_shard_ms(), 0.0, "no traffic on the faulted shard");
    });
}

#[test]
fn extension_only_faults_never_finish_earlier() {
    // Blackouts and slow-down brownouts may only *extend* service: every
    // start event on the faulted pool finishes at or after its fault-free
    // twin, and the injected extension equals the summed per-event delta.
    prop::forall(40, |g| {
        let n_shards = g.usize_in(1, 4);
        let cfg = ProviderCfg {
            max_concurrency: g.usize_in(1, 3),
            jitter_sigma: if g.bool() { 0.1 } else { 0.0 },
            ..ProviderCfg::default()
        };
        let seed = g.u64();
        let plan = random_extension_only_plan(g, n_shards);
        let clean_cfg =
            PoolCfg { shards: vec![cfg.clone(); n_shards], faults: FaultPlan::default() };
        let fault_cfg = PoolCfg { shards: vec![cfg; n_shards], faults: plan };
        let mut clean = ProviderPool::new(&clean_cfg, Rng::new(seed));
        let mut faulted = ProviderPool::new(&fault_cfg, Rng::new(seed));

        let mut now = 0.0f64;
        let mut inflight: Vec<usize> = Vec::new();
        let mut extension = 0.0f64;
        let mut next_id = 0usize;
        for _ in 0..g.usize_in(1, 80) {
            now += g.f64_in(0.0, 60.0);
            if inflight.is_empty() || g.bool() {
                let shard = g.usize_in(0, n_shards);
                let tokens = g.f64_in(1.0, 2000.0);
                let a = clean.submit(next_id, tokens, shard, now);
                let b = faulted.submit(next_id, tokens, shard, now);
                match (a, b) {
                    (None, None) => {}
                    (Some(ca), Some(fa)) => {
                        assert_eq!(ca.id, fa.id);
                        assert!(fa.finish_ms >= ca.finish_ms, "fault sped a request up");
                        extension += fa.finish_ms - ca.finish_ms;
                        inflight.push(ca.id);
                    }
                    _ => panic!("admission diverged at id {next_id}"),
                }
                next_id += 1;
            } else {
                let id = inflight.swap_remove(g.usize_in(0, inflight.len()));
                let a = clean.on_finish(id, now);
                let b = faulted.on_finish(id, now);
                assert_eq!(a.len(), b.len(), "promotion counts diverged finishing {id}");
                for (ca, fa) in a.iter().zip(&b) {
                    assert_eq!(ca.id, fa.id);
                    assert!(fa.finish_ms >= ca.finish_ms, "fault sped a promotion up");
                    extension += fa.finish_ms - ca.finish_ms;
                    inflight.push(ca.id);
                }
            }
        }
        let got = faulted.faulted_shard_ms();
        assert!(
            (got - extension).abs() <= 1e-6 * extension.max(1.0),
            "faulted_shard_ms {got} != summed per-event extension {extension}"
        );
    });
}
