//! Property tests for the sharded provider pool.
//!
//! The load-bearing contract: a 1-shard [`ProviderPool`] is **bit-identical**
//! to a bare [`MockProvider`] on arbitrary submit/finish sequences — same
//! `Started` events (jitter bits included), same promotions, same
//! introspection counters. Every pre-pool experiment CSV rests on this.

use blackbox_sched::provider::pool::{PoolCfg, ProviderPool};
use blackbox_sched::provider::{MockProvider, ProviderCfg};
use blackbox_sched::testing::prop;
use blackbox_sched::util::rng::Rng;

#[test]
fn one_shard_pool_is_bit_identical_to_bare_provider() {
    prop::forall(60, |g| {
        let cfg = ProviderCfg {
            base_ms: g.f64_in(50.0, 500.0),
            per_token_ms: g.f64_in(0.1, 5.0),
            max_concurrency: g.usize_in(1, 8),
            slowdown_gamma: g.f64_in(0.0, 2.0),
            slowdown_exp: g.f64_in(0.5, 2.0),
            slowdown_ref: g.f64_in(1.0, 10.0),
            jitter_sigma: if g.bool() { g.f64_in(0.01, 0.3) } else { 0.0 },
        };
        let seed = g.u64();
        let rng = Rng::new(seed).derive("provider");
        let mut bare = MockProvider::new(cfg.clone(), rng.clone());
        let mut pool = ProviderPool::new(&PoolCfg::single(cfg), rng);

        // Requests currently *running* (finish is only legal for these —
        // the DES only ever fires ProviderDone for started requests).
        let mut started_ids: Vec<usize> = Vec::new();
        let mut next_id = 0usize;
        let mut now = 0.0f64;
        let n_ops = g.usize_in(1, 120);
        for _ in 0..n_ops {
            now += g.f64_in(0.0, 50.0);
            if started_ids.is_empty() || g.bool() {
                let tokens = g.f64_in(1.0, 2000.0);
                let a = bare.submit(next_id, tokens, now);
                let b = pool.submit(next_id, tokens, 0, now);
                assert_eq!(a, b, "submit diverged at id {next_id}");
                if let Some(s) = a {
                    assert_eq!(s.id, next_id);
                    started_ids.push(s.id);
                }
                next_id += 1;
            } else {
                let pick = g.usize_in(0, started_ids.len());
                let id = started_ids.swap_remove(pick);
                let a = bare.on_finish(now);
                let b = pool.on_finish(id, now);
                assert_eq!(a, b, "promotions diverged finishing {id}");
                for s in &a {
                    started_ids.push(s.id);
                }
            }
            assert_eq!(bare.running(), pool.total_running());
            assert_eq!(bare.hidden_queue_len(), pool.hidden_queue_len());
        }
        assert_eq!(bare.peak_hidden_queue(), pool.peak_hidden_queue());
        assert_eq!(bare.total_started(), pool.total_started());
    });
}

#[test]
fn multi_shard_pool_conserves_every_request() {
    prop::forall(40, |g| {
        let n_shards = g.usize_in(2, 5);
        let cfg = ProviderCfg {
            max_concurrency: g.usize_in(1, 4),
            jitter_sigma: 0.05,
            ..ProviderCfg::default()
        };
        let pool_cfg = PoolCfg { shards: vec![cfg; n_shards] };
        let mut pool = ProviderPool::new(&pool_cfg, Rng::new(g.u64()));

        let n = g.usize_in(1, 60);
        let mut started_ids: Vec<usize> = Vec::new();
        for id in 0..n {
            let shard = g.usize_in(0, n_shards);
            if let Some(s) = pool.submit(id, g.f64_in(10.0, 3000.0), shard, 0.0) {
                started_ids.push(s.id);
            }
        }
        // Finish everything in arbitrary order; promotions keep the fleet
        // flowing until every submitted request has run.
        let mut finished = 0usize;
        while let Some(pos) = (!started_ids.is_empty()).then(|| g.usize_in(0, started_ids.len())) {
            let id = started_ids.swap_remove(pos);
            finished += 1;
            for s in pool.on_finish(id, finished as f64) {
                started_ids.push(s.id);
            }
        }
        assert_eq!(finished, n, "every submitted request eventually runs and finishes");
        assert_eq!(pool.total_started(), n as u64);
        assert_eq!(pool.total_running(), 0);
        assert_eq!(pool.hidden_queue_len(), 0);
        assert_eq!(pool.started_by_shard().iter().sum::<u64>(), n as u64);
    });
}
