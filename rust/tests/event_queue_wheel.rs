//! Backend-equivalence property tests for the event queue.
//!
//! The wheel-backed `EventQueue` must be **pop-for-pop identical** to the
//! retained `BinaryHeap` reference on arbitrary interleavings of `push` /
//! `push_cancelable` / `cancel` / `pop` — same `(time, seq)` stream, same
//! `processed()` / `skipped()` counters. Debug builds already cross-check
//! every pop against an internal shadow heap; these tests drive the two
//! public backends side by side so the contract also holds in **release**
//! mode, where the shadow (like every `debug_assert!`) is compiled out.

use blackbox_sched::sim::{BackendKind, EventQueue, TimerId};
use blackbox_sched::testing::prop::{self, Gen};

/// Drive both backends through one identical randomized op script, with
/// event times drawn by `time_of`. Asserts bit-identical pop streams,
/// cancel results, peeks, and counters.
fn exercise(g: &mut Gen, mut time_of: impl FnMut(&mut Gen, f64) -> f64) {
    let mut wheel = EventQueue::with_backend(BackendKind::Wheel);
    let mut heap = EventQueue::with_backend(BackendKind::Heap);
    let mut wheel_ids: Vec<TimerId> = Vec::new();
    let mut heap_ids: Vec<TimerId> = Vec::new();
    let mut now = 0.0_f64;
    let n_ops = g.usize_in(1, 200);
    for tag in 0..n_ops {
        match g.usize_in(0, 10) {
            // Plain event.
            0..=3 => {
                let t = time_of(&mut *g, now);
                wheel.push(t, tag);
                heap.push(t, tag);
            }
            // Cancelable timer (ids recorded per queue — never shared).
            4..=6 => {
                let t = time_of(&mut *g, now);
                wheel_ids.push(wheel.push_cancelable(t, tag));
                heap_ids.push(heap.push_cancelable(t, tag));
            }
            // Cancel a random previously issued id — possibly one that
            // already fired or was already canceled (must agree on false).
            7..=8 => {
                if !wheel_ids.is_empty() {
                    let i = g.usize_in(0, wheel_ids.len());
                    assert_eq!(wheel.cancel(wheel_ids[i]), heap.cancel(heap_ids[i]));
                }
            }
            // Pop, advancing "now" so later pushes stay DES-shaped.
            _ => {
                let (w, h) = (wheel.pop(), heap.pop());
                assert_eq!(
                    w.as_ref().map(|(t, p)| (t.to_bits(), *p)),
                    h.as_ref().map(|(t, p)| (t.to_bits(), *p)),
                    "pop divergence mid-script"
                );
                if let Some((t, _)) = w {
                    now = now.max(t);
                }
            }
        }
    }
    // Drain both queues to empty, peeking before every pop.
    loop {
        assert_eq!(
            wheel.peek_time().map(f64::to_bits),
            heap.peek_time().map(f64::to_bits),
            "peek divergence during drain"
        );
        let (w, h) = (wheel.pop(), heap.pop());
        assert_eq!(
            w.as_ref().map(|(t, p)| (t.to_bits(), *p)),
            h.as_ref().map(|(t, p)| (t.to_bits(), *p)),
            "pop divergence during drain"
        );
        if w.is_none() {
            break;
        }
    }
    assert_eq!(wheel.processed(), heap.processed());
    assert_eq!(wheel.skipped(), heap.skipped());
    assert_eq!(wheel.len(), 0);
    assert_eq!(heap.len(), 0);
}

#[test]
fn wheel_matches_heap_on_randomized_op_sequences() {
    // The full time spectrum: exact tick edges, sub-tick jitter, multi-level
    // wheel distances, and far-future times past the 2^36-tick horizon.
    prop::forall(120, |g| {
        exercise(g, |g, now| match g.usize_in(0, 4) {
            0 => (now + g.f64_in(0.0, 3.0)).floor(),
            1 => now + g.f64_in(0.0, 2.0),
            2 => now + g.f64_in(0.0, 5_000.0),
            _ => now + g.f64_in(0.0, 1.0e11),
        });
    });
}

#[test]
fn wheel_matches_heap_across_cascades_and_cancels() {
    // Times concentrated at level ≥ 1 distances (64..16384 ticks out), so
    // pops of nearer events constantly force cascades while cancels land on
    // entries parked mid-wheel — the "timer cancel during cascade" surface.
    prop::forall(120, |g| {
        exercise(g, |g, now| {
            if g.bool() {
                now + g.f64_in(64.0, 16_384.0)
            } else {
                now + g.f64_in(0.0, 4.0)
            }
        });
    });
}

#[test]
fn wheel_matches_heap_on_same_tick_bursts() {
    // Many events inside one or two ticks: the FIFO-by-(time, seq) contract
    // at and across the tick boundary, where quantization would bite first.
    prop::forall(120, |g| {
        exercise(g, |g, now| now.floor() + g.f64_in(0.0, 2.0));
    });
}

#[test]
fn cancel_after_pop_returns_false_on_both_backends() {
    for kind in [BackendKind::Wheel, BackendKind::Heap] {
        let mut q = EventQueue::with_backend(kind);
        let t = q.push_cancelable(2.0, "x");
        assert_eq!(q.pop(), Some((2.0, "x")));
        assert!(!q.cancel(t), "{kind:?}: cancel after fire must return false");
        assert_eq!(q.processed(), 1);
        assert_eq!(q.skipped(), 0);
    }
}

#[test]
fn same_tick_fifo_across_tick_boundary_on_both_backends() {
    for kind in [BackendKind::Wheel, BackendKind::Heap] {
        let mut q = EventQueue::with_backend(kind);
        q.push(5.0, "b");
        q.push(4.999, "a");
        q.push(5.0, "c"); // exact tie with "b": seq order decides
        q.push(5.001, "d");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec!["a", "b", "c", "d"], "{kind:?}");
    }
}

#[test]
fn timer_cancel_during_cascade_on_both_backends() {
    for kind in [BackendKind::Wheel, BackendKind::Heap] {
        let mut q = EventQueue::with_backend(kind);
        // 65/68/70 share a level-1 wheel slot from tick 0; popping 65
        // cascades the rest to level 0. Cancel one only after the cascade.
        let t = q.push_cancelable(70.0, "timer");
        q.push(65.0, "a");
        q.push(68.0, "b");
        assert_eq!(q.pop(), Some((65.0, "a")), "{kind:?}");
        assert!(q.cancel(t), "{kind:?}: cancelable after cascade");
        assert_eq!(q.pop(), Some((68.0, "b")), "{kind:?}");
        assert_eq!(q.pop(), None, "{kind:?}: canceled cascaded timer never fires");
        assert_eq!(q.skipped(), 1, "{kind:?}");
    }
}
