//! Property: every incremental ordering index (SJF / EDF / FeasibleSet)
//! must reproduce the retained O(n) reference scan **bit-for-bit** —
//! same winner, same tie rules — on production-shaped op sequences:
//! monotone event-time pushes, interleaved removes (dispatch and timeout
//! cancels), and deferred re-pushes with past arrivals through
//! `push_ordered` (the DES contract that keeps the class lists
//! arrival-sorted). In the style of the slab-vs-model queue test.
//!
//! This is the release-mode gate for the PR-5 bit-compat contract: debug
//! builds additionally assert the same equivalence inside every
//! `Ordering::select`, but `cargo test --release` disables those, so the
//! explicit comparison here is what keeps the contract enforced where the
//! benchmarks run.

use blackbox_sched::core::{Class, Priors, TokenBucket};
use blackbox_sched::predictor::Route;
use blackbox_sched::scheduler::ordering::{
    Edf, FeasibleSet, Fifo, Ordering, OrderingCfg, RobustSjf, Sjf,
};
use blackbox_sched::scheduler::queues::{ClassQueues, SchedRequest};
use blackbox_sched::testing::prop;

fn sreq(id: usize, arrival: f64, p50: f64, width: f64, deadline: f64) -> SchedRequest {
    SchedRequest {
        id,
        arrival_ms: arrival,
        deadline_ms: deadline,
        // Long bucket: everything routes to the heavy class, the one whose
        // ordering is scored. Width 0 = point prior (the pre-interval
        // representation); > 0 exercises the uncertainty-aware keys.
        priors: Priors::with_width(p50, p50 * 1.5, width),
        route: Route::from_bucket(TokenBucket::Long),
        defer_attempts: 0,
    }
}

/// Run `cases` random production-shaped op sequences against a fresh
/// ordering per case, asserting index == reference after every op.
fn exercise(mk: impl Fn() -> Box<dyn Ordering>, cases: usize) {
    prop::forall(cases, |g| {
        let mut ord = mk();
        let mut q = ClassQueues::new();
        let mut clock = 0.0f64;
        let mut next_id = 0usize;
        let mut live: Vec<usize> = Vec::new();
        let n_ops = g.usize_in(20, 120);
        for _ in 0..n_ops {
            match g.usize_in(0, 10) {
                // New arrival: event time only moves forward. Discrete p50
                // and deadline choices make exact key ties reachable, so
                // the documented tie rules are actually exercised.
                0..=3 => {
                    clock += g.f64_in(0.0, 40.0);
                    let p50 = if g.bool() {
                        *g.choice(&[100.0, 250.0, 700.0, 1800.0])
                    } else {
                        g.f64_in(10.0, 3000.0)
                    };
                    // Interval widths: zero (point priors), a discrete
                    // rung (robust-cost key ties reachable), or continuous
                    // (every prior distinct — the quantized-grouping
                    // regime).
                    let width = match g.usize_in(0, 3) {
                        0 => 0.0,
                        1 => *g.choice(&[50.0, 400.0]),
                        _ => g.f64_in(0.0, p50),
                    };
                    let slack = if g.bool() {
                        *g.choice(&[800.0, 2_500.0, 20_000.0])
                    } else {
                        g.f64_in(200.0, 60_000.0)
                    };
                    let r = sreq(next_id, clock, p50, width, clock + slack);
                    next_id += 1;
                    live.push(r.id);
                    ord.on_push(&r, clock);
                    q.push(r);
                }
                // Deferred re-push: the request arrived in the past and
                // re-enters arrival-sorted; its deadline may already have
                // passed (past-deadline work is legal queue content).
                4..=5 => {
                    clock += g.f64_in(0.0, 10.0);
                    let arrival = g.f64_in(0.0, clock);
                    let p50 = g.f64_in(10.0, 3000.0);
                    let r = sreq(
                        next_id,
                        arrival,
                        p50,
                        g.f64_in(0.0, p50),
                        arrival + g.f64_in(100.0, 30_000.0),
                    );
                    next_id += 1;
                    live.push(r.id);
                    ord.on_push(&r, clock);
                    q.push_ordered(r);
                }
                // Remove by id: dispatch of some winner, or a timeout
                // cancel of an arbitrary queued request.
                6..=7 => {
                    if !live.is_empty() {
                        let i = g.usize_in(0, live.len());
                        let id = live.swap_remove(i);
                        let r = q.remove_id(id).expect("live id queued");
                        ord.on_remove(&r);
                    }
                }
                // Idle gap: let scores drift / feasibility windows close so
                // the lazy-rescore and expiry paths are exercised.
                _ => {
                    clock += g.f64_in(0.0, 500.0);
                }
            }
            let got = ord.select(q.view(Class::Heavy), clock);
            let want = ord.reference_select(q.view(Class::Heavy), clock);
            assert_eq!(
                got,
                want,
                "{} index diverged from the reference scan at now={clock} depth={}",
                ord.name(),
                live.len()
            );
            if live.is_empty() {
                assert_eq!(got, None);
            } else {
                assert!(got.is_some(), "non-empty queue must yield a winner");
            }
        }
    });
}

#[test]
fn sjf_index_matches_reference_scan() {
    exercise(|| Box::new(Sjf::new()) as Box<dyn Ordering>, 80);
}

#[test]
fn edf_index_matches_reference_scan() {
    exercise(|| Box::new(Edf::new()) as Box<dyn Ordering>, 80);
}

#[test]
fn robust_sjf_index_matches_reference_scan() {
    exercise(|| Box::new(RobustSjf::new()) as Box<dyn Ordering>, 80);
}

#[test]
fn feasible_set_index_matches_reference_scan() {
    exercise(|| Box::new(FeasibleSet::new(OrderingCfg::default())) as Box<dyn Ordering>, 80);
}

#[test]
fn feasible_set_quantized_index_matches_reference_scan() {
    // Quantized grouping shares the reference scan with the exact path:
    // winners and tie rules must be bit-identical even though the group
    // keys coarsen (the generator's continuous p50 draws make every prior
    // distinct, so the bins actually hold mixed-score populations here).
    exercise(|| Box::new(FeasibleSet::new(OrderingCfg::quantized())) as Box<dyn Ordering>, 80);
}

#[test]
fn fifo_select_is_its_own_reference() {
    exercise(|| Box::new(Fifo) as Box<dyn Ordering>, 20);
}
