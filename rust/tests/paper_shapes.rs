//! Integration: the paper's headline qualitative claims, asserted as tests
//! (reduced seed counts — the full tables come from `bbsched exp`).

use blackbox_sched::experiments::runner::{run_cell, CellSpec, Congestion, Regime};
use blackbox_sched::metrics::Aggregate;
use blackbox_sched::predictor::InfoLevel;
use blackbox_sched::scheduler::overload::BucketPolicy;
use blackbox_sched::scheduler::{SchedulerCfg, StrategyKind};
use blackbox_sched::workload::Mix;

const SEEDS: u64 = 3;
const N: usize = 200;

fn mean(runs: &[blackbox_sched::metrics::RunMetrics], f: impl Fn(&blackbox_sched::metrics::RunMetrics) -> f64) -> f64 {
    Aggregate::new(runs).mean_std(f).0
}

fn final_cell(regime: Regime, info: InfoLevel) -> Vec<blackbox_sched::metrics::RunMetrics> {
    run_cell(
        &CellSpec::new(regime, SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc), N)
            .with_info(info),
        SEEDS,
    )
}

#[test]
fn ladder_magnitude_is_the_threshold_for_short_tails() {
    // §4.4: removing magnitude priors inflates short P95 by large factors in
    // stressed cells; class labels alone recover most routing benefit.
    let bh = Regime { mix: Mix::Balanced, congestion: Congestion::High };
    let blind = mean(&final_cell(bh, InfoLevel::NoInfo), |m| m.short_p95_ms);
    let class_only = mean(&final_cell(bh, InfoLevel::ClassOnly), |m| m.short_p95_ms);
    let coarse = mean(&final_cell(bh, InfoLevel::Coarse), |m| m.short_p95_ms);
    let oracle = mean(&final_cell(bh, InfoLevel::Oracle), |m| m.short_p95_ms);
    assert!(blind > 2.0 * coarse, "no-info {blind:.0} vs coarse {coarse:.0}");
    assert!(class_only < blind * 0.6, "class routing must recover most of the gap");
    // Oracle tracks coarse: the practical bar is coarse magnitude.
    assert!((oracle - coarse).abs() < 0.35 * coarse, "oracle {oracle:.0} vs coarse {coarse:.0}");
}

#[test]
fn ladder_degrades_satisfaction_when_blind() {
    let hh = Regime { mix: Mix::Heavy, congestion: Congestion::High };
    let blind = mean(&final_cell(hh, InfoLevel::NoInfo), |m| m.satisfaction);
    let coarse = mean(&final_cell(hh, InfoLevel::Coarse), |m| m.satisfaction);
    assert!(coarse > blind + 0.1, "coarse {coarse:.2} vs blind {blind:.2}");
}

#[test]
fn full_stack_holds_the_balanced_high_headline() {
    // §4.5: under balanced/high the full stack reaches full completion and
    // satisfaction with short P95 within tens of ms of quota-tiered.
    let bh = Regime { mix: Mix::Balanced, congestion: Congestion::High };
    let full = run_cell(
        &CellSpec::new(bh, SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc), N),
        SEEDS,
    );
    let quota = run_cell(
        &CellSpec::new(bh, SchedulerCfg::for_strategy(StrategyKind::QuotaTiered), N),
        SEEDS,
    );
    assert!(mean(&full, |m| m.completion_rate) > 0.99);
    assert!(mean(&full, |m| m.satisfaction) > 0.97);
    let gap = mean(&full, |m| m.short_p95_ms) - mean(&quota, |m| m.short_p95_ms);
    assert!(gap.abs() < 150.0, "short-P95 gap vs quota: {gap:.0} ms");
}

#[test]
fn cost_ladder_beats_uniform_mild_on_goodput() {
    // §4.7: gentle class-agnostic admission hides overload in the queue and
    // collapses useful goodput; the ladder sheds legibly and keeps it.
    let hh = Regime { mix: Mix::Heavy, congestion: Congestion::High };
    let run_policy = |policy: BucketPolicy| {
        let mut sched = SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc);
        sched.overload.bucket_policy = policy;
        run_cell(&CellSpec::new(hh, sched, N), SEEDS)
    };
    let ladder = run_policy(BucketPolicy::CostLadder);
    let mild = run_policy(BucketPolicy::UniformMild);
    assert!(
        mean(&ladder, |m| m.goodput_rps) > 1.3 * mean(&mild, |m| m.goodput_rps),
        "ladder {:.2} vs mild {:.2}",
        mean(&ladder, |m| m.goodput_rps),
        mean(&mild, |m| m.goodput_rps)
    );
    // Mild (almost) never rejects — overload hides as mass deferral. The
    // censored global-tail fix (PR 5) lets sustained in-flight timeouts
    // push severity past mild's lone reject threshold occasionally, so the
    // paper's qualitative claim is "rare", not a hard zero.
    let mild_rejects = mean(&mild, |m| m.rejects_total as f64);
    assert!(mild_rejects < 0.02 * N as f64, "mild rejects {mild_rejects} per run is not rare");
    assert!(mean(&mild, |m| m.defers_total as f64) > 2.0 * mean(&ladder, |m| m.defers_total as f64));
}

#[test]
fn rejections_concentrate_on_xlong() {
    // Figure 5: the default ladder's rejections land on xlong; long is
    // mostly deferred; medium is untouched.
    let hh = Regime { mix: Mix::Heavy, congestion: Congestion::High };
    let runs = run_cell(
        &CellSpec::new(hh, SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc), N),
        SEEDS,
    );
    let mut rejects = [0u64; 5];
    let mut defers = [0u64; 5];
    for m in &runs {
        for i in 0..5 {
            rejects[i] += m.rejects_by_bucket[i];
            defers[i] += m.defers_by_bucket[i];
        }
    }
    assert_eq!(rejects[0], 0, "short");
    assert_eq!(rejects[1], 0, "medium");
    assert!(rejects[3] > rejects[2], "xlong bears the majority of rejections: {rejects:?}");
    assert!(defers[2] > 0, "longs are deferred under stress: {defers:?}");
}

#[test]
fn noise_sweep_degrades_gracefully() {
    // §4.10: up to 60% multiplicative prior error must not collapse the
    // joint operating point.
    let bh = Regime { mix: Mix::Balanced, congestion: Congestion::High };
    let base = run_cell(
        &CellSpec::new(bh, SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc), N),
        SEEDS,
    );
    let noisy = run_cell(
        &CellSpec::new(bh, SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc), N)
            .with_noise(0.6),
        SEEDS,
    );
    let cr_drop = mean(&base, |m| m.completion_rate) - mean(&noisy, |m| m.completion_rate);
    assert!(cr_drop < 0.05, "CR collapse under noise: {cr_drop}");
    let p95_ratio = mean(&noisy, |m| m.short_p95_ms) / mean(&base, |m| m.short_p95_ms);
    assert!(p95_ratio < 1.5, "short tail blow-up under noise: {p95_ratio}");
}

#[test]
fn threshold_perturbation_is_stable() {
    // §4.9: ±20% on cutoffs/backoff moves joint metrics only modestly.
    let bh = Regime { mix: Mix::Balanced, congestion: Congestion::High };
    let run_factor = |f: f64| {
        let mut sched = SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc);
        sched.overload = sched.overload.perturbed(f);
        run_cell(&CellSpec::new(bh, sched, N), SEEDS)
    };
    let base = run_factor(1.0);
    for f in [0.8, 1.2] {
        let pert = run_factor(f);
        assert!(mean(&pert, |m| m.completion_rate) > 0.97, "factor {f}");
        let sat_drift =
            (mean(&pert, |m| m.satisfaction) - mean(&base, |m| m.satisfaction)).abs();
        assert!(sat_drift < 0.08, "factor {f}: satisfaction drift {sat_drift}");
    }
}

#[test]
fn fair_queuing_taxes_longs_less_than_short_priority() {
    // Table 4 direction: both improve shorts over paced FIFO; FQ's long
    // overhead stays at or below Short-Priority's.
    use blackbox_sched::core::SloPolicy;
    let regime = Regime { mix: Mix::FairnessHeavy, congestion: Congestion::High };
    let run_alloc = |strategy: StrategyKind| {
        let mut sched = SchedulerCfg::for_strategy(strategy);
        sched.interactive_bypass = 0;
        sched.max_inflight = 2;
        let mut spec = CellSpec::new(regime, sched, N);
        spec.rate_rps = 0.75;
        spec.provider.base_ms = 2000.0;
        spec.slo = SloPolicy { timeout_factor: 20.0, ..SloPolicy::default() };
        run_cell(&spec, SEEDS)
    };
    let fifo = run_alloc(StrategyKind::PacedFifo);
    let sp = run_alloc(StrategyKind::ShortPriority);
    let fq = run_alloc(StrategyKind::FairQueuing);
    let short = |runs: &[blackbox_sched::metrics::RunMetrics]| mean(runs, |m| m.short_p90_ms);
    let long = |runs: &[blackbox_sched::metrics::RunMetrics]| mean(runs, |m| m.heavy_p90_ms);
    assert!(short(&sp) < 0.5 * short(&fifo), "SP must protect shorts");
    assert!(short(&fq) < 0.5 * short(&fifo), "FQ must protect shorts");
    assert!(long(&sp) > long(&fifo), "SP taxes longs");
    assert!(
        long(&fq) <= long(&sp) * 1.02,
        "FQ tax {:.0} must not exceed SP tax {:.0}",
        long(&fq),
        long(&sp)
    );
}
