//! The partitioned event loop's bit-compat contract: for any partition
//! count, [`run_tenants_partitioned`] must be **byte-identical** to the
//! serial reference loop (`partitions == 1`) — same per-tenant metric
//! bits, same per-request outcomes (status, latency bits, defer counts),
//! same engine diagnostics including the f64 queue-depth integral and the
//! per-shard start counts — across strategies × fleets × tenant mixes ×
//! seeds. This is the same bit-compat-ladder discipline as the 1-shard
//! and 1-tenant equivalences (`tests/pool_equivalence.rs`,
//! `tests/tenant_equivalence.rs`), one rung up.
//!
//! The release-mode leg of CI is load-bearing here: the window-boundary
//! shadow checks are `debug_assert!`s, so the release run proves the
//! protocol itself (not the asserts) carries the equality.

use blackbox_sched::predictor::{InfoLevel, LadderSource};
use blackbox_sched::provider::fault::FaultPlan;
use blackbox_sched::provider::pool::PoolCfg;
use blackbox_sched::provider::ProviderCfg;
use blackbox_sched::scheduler::{OrderingCfg, OrderingKind, SchedulerCfg, ShardPolicy, StrategyKind};
use blackbox_sched::sim::driver::{
    run_pool_partitioned, run_tenants_partitioned, run_tenants_partitioned_with_bound,
    MultiRunOutput, RunOutput, TenantSpec,
};
use blackbox_sched::sim::partition::{FallbackReason, WindowBound};
use blackbox_sched::util::rng::Rng;
use blackbox_sched::workload::{Mix, WorkloadSpec};

/// Assert two multi-tenant outputs are bitwise identical: tenant metrics
/// (f64s compared by bits), every outcome, and the full diagnostics.
fn outputs_bitwise_equal(a: &MultiRunOutput, b: &MultiRunOutput, ctx: &str) {
    assert_eq!(a.tenants.len(), b.tenants.len(), "{ctx}");
    for (t, (x, y)) in a.tenants.iter().zip(b.tenants.iter()).enumerate() {
        assert_eq!(x.sends, y.sends, "{ctx}: tenant {t} sends");
        assert_eq!(x.metrics.n_offered, y.metrics.n_offered, "{ctx}: tenant {t}");
        assert_eq!(x.metrics.n_completed, y.metrics.n_completed, "{ctx}: tenant {t}");
        assert_eq!(x.metrics.n_rejected, y.metrics.n_rejected, "{ctx}: tenant {t}");
        assert_eq!(x.metrics.n_timed_out, y.metrics.n_timed_out, "{ctx}: tenant {t}");
        assert_eq!(x.metrics.defers_total, y.metrics.defers_total, "{ctx}: tenant {t}");
        assert_eq!(x.metrics.rejects_total, y.metrics.rejects_total, "{ctx}: tenant {t}");
        assert_eq!(x.metrics.defers_by_bucket, y.metrics.defers_by_bucket, "{ctx}: tenant {t}");
        assert_eq!(x.metrics.rejects_by_bucket, y.metrics.rejects_by_bucket, "{ctx}: tenant {t}");
        assert_eq!(
            x.metrics.feasibility_violations,
            y.metrics.feasibility_violations,
            "{ctx}: tenant {t}"
        );
        assert_eq!(x.metrics.completed_by_bucket, y.metrics.completed_by_bucket, "{ctx}: {t}");
        assert_eq!(x.metrics.offered_by_bucket, y.metrics.offered_by_bucket, "{ctx}: {t}");
        for (m, n) in [
            (x.metrics.short_p95_ms, y.metrics.short_p95_ms),
            (x.metrics.short_p90_ms, y.metrics.short_p90_ms),
            (x.metrics.global_p95_ms, y.metrics.global_p95_ms),
            (x.metrics.global_std_ms, y.metrics.global_std_ms),
            (x.metrics.heavy_p90_ms, y.metrics.heavy_p90_ms),
            (x.metrics.completion_rate, y.metrics.completion_rate),
            (x.metrics.satisfaction, y.metrics.satisfaction),
            (x.metrics.goodput_rps, y.metrics.goodput_rps),
            (x.metrics.makespan_ms, y.metrics.makespan_ms),
        ] {
            assert_eq!(m.to_bits(), n.to_bits(), "{ctx}: tenant {t} metric drift {m} vs {n}");
        }
        assert_eq!(x.outcomes.len(), y.outcomes.len(), "{ctx}: tenant {t}");
        for (o, p) in x.outcomes.iter().zip(y.outcomes.iter()) {
            assert_eq!(o.id, p.id, "{ctx}");
            assert_eq!(o.status, p.status, "{ctx}: request {}", o.id);
            assert_eq!(
                o.latency_ms.map(f64::to_bits),
                p.latency_ms.map(f64::to_bits),
                "{ctx}: request {} latency bits",
                o.id
            );
            assert_eq!(o.defer_count, p.defer_count, "{ctx}: request {}", o.id);
        }
    }
    let (da, db) = (&a.diagnostics, &b.diagnostics);
    assert_eq!(da.events_processed, db.events_processed, "{ctx}");
    assert_eq!(da.events_skipped, db.events_skipped, "{ctx}");
    assert_eq!(da.timers_canceled, db.timers_canceled, "{ctx}");
    assert_eq!(da.sends, db.sends, "{ctx}");
    assert_eq!(da.peak_provider_queue, db.peak_provider_queue, "{ctx}");
    assert_eq!(da.peak_inflight, db.peak_inflight, "{ctx}");
    assert_eq!(da.started_by_shard, db.started_by_shard, "{ctx}");
    assert_eq!(
        da.mean_queue_depth.to_bits(),
        db.mean_queue_depth.to_bits(),
        "{ctx}: depth integral drift {} vs {}",
        da.mean_queue_depth,
        db.mean_queue_depth
    );
    assert_eq!(da.peak_queue_depth, db.peak_queue_depth, "{ctx}");
    assert_eq!(da.ordering_select_work, db.ordering_select_work, "{ctx}");
    assert_eq!(da.ordering_group_count, db.ordering_group_count, "{ctx}");
    assert_eq!(da.ordering_scan_fallbacks, db.ordering_scan_fallbacks, "{ctx}");
}

/// A heterogeneous 4-tenant mix: different workloads, rates, request
/// counts, and shard policies, all on the given strategy.
fn tenant_mix(strategy: StrategyKind) -> Vec<TenantSpec> {
    let shapes = [
        (Mix::Balanced, 50usize, 9.0),
        (Mix::Heavy, 70, 6.0),
        (Mix::Balanced, 60, 12.0),
        (Mix::Heavy, 40, 4.0),
    ];
    shapes
        .iter()
        .enumerate()
        .map(|(t, &(mix, n, rate))| {
            let mut sched = SchedulerCfg::for_strategy(strategy);
            sched.shards.policy = ShardPolicy::ALL[t % ShardPolicy::ALL.len()];
            TenantSpec {
                workload: WorkloadSpec::new(mix, n, rate),
                sched,
                info: InfoLevel::Coarse,
                noise: 0.0,
            }
        })
        .collect()
}

#[test]
fn partitioned_matches_serial_bit_for_bit() {
    let fleets = [
        ("split4", PoolCfg::split(ProviderCfg::default(), 4)),
        ("hetero3", PoolCfg::heterogeneous(ProviderCfg::default(), 3, 0.4)),
    ];
    for seed in 0..3u64 {
        for (fleet_name, pool) in &fleets {
            for strategy in StrategyKind::ALL {
                let specs = tenant_mix(strategy);
                let serial = run_tenants_partitioned(&specs, pool, seed, 1);
                assert_eq!(serial.partition.partitions, 1);
                for partitions in [2usize, 3, 4] {
                    let ctx = format!("seed {seed}, {fleet_name}, {strategy:?}, P={partitions}");
                    let par = run_tenants_partitioned(&specs, pool, seed, partitions);
                    assert_eq!(
                        par.partition.partitions, partitions,
                        "{ctx}: the parallel path must actually run"
                    );
                    assert!(par.partition.serial_fallback.is_none(), "{ctx}");
                    assert!(par.partition.windows > 0, "{ctx}: windows advanced");
                    assert!(par.partition.lookahead_ms > 0.0, "{ctx}");
                    outputs_bitwise_equal(&par, &serial, &ctx);
                }
            }
        }
    }
}

#[test]
fn noisy_interval_tenants_partition_bit_for_bit() {
    // Continuous noisy priors plus the full uncertainty stack — robust-SJF
    // width demotion, quantized feasible-set grouping, and the online
    // recalibrator — through the partitioned loop. Each tenant's noise
    // stream derives from its own tenant seed, so injection must be
    // byte-identical no matter how tenants are carved across partition
    // threads.
    let mut specs = tenant_mix(StrategyKind::AdaptiveDrr);
    for (t, spec) in specs.iter_mut().enumerate() {
        spec.noise = [0.4, 0.2, 0.4, 0.0][t];
        spec.sched.recalibrate = t % 2 == 0;
    }
    specs[0].sched.heavy_ordering = OrderingKind::RobustSjf;
    specs[1].sched.heavy_ordering = OrderingKind::FeasibleSet;
    specs[1].sched.ordering = OrderingCfg::quantized();
    specs[2].sched.heavy_ordering = OrderingKind::Sjf;
    let pool = PoolCfg::split(ProviderCfg::default(), 3);
    for seed in 0..3u64 {
        let serial = run_tenants_partitioned(&specs, &pool, seed, 1);
        for partitions in [2usize, 4] {
            let ctx = format!("noisy tenants, seed {seed}, P={partitions}");
            let par = run_tenants_partitioned(&specs, &pool, seed, partitions);
            assert!(par.partition.serial_fallback.is_none(), "{ctx}");
            outputs_bitwise_equal(&par, &serial, &ctx);
        }
    }
}

#[test]
fn boundary_exact_events_defer_and_still_match() {
    // Deterministic service physics: no jitter, no per-token cost, no
    // congestion slowdown, so *every* service time is exactly `base_ms`
    // and the lookahead window is exactly `base_ms` wide. A submission at
    // a window's start then completes exactly on its window end — the
    // strict `t < end` rule must defer it to the next window, and the
    // merged result must still be bit-identical to serial.
    let shard = ProviderCfg {
        base_ms: 25.0,
        per_token_ms: 0.0,
        jitter_sigma: 0.0,
        slowdown_gamma: 0.0,
        max_concurrency: 4,
        ..ProviderCfg::default()
    };
    let pool = PoolCfg::split(shard, 2);
    // Saturate the 4 service slots (~180 rps against 25 ms services) so
    // queued submissions chain off completions: every chained start lands
    // on a `t0 + 25k` lattice shared across partitions through the common
    // pool, which is what manufactures exact peek == window-end hits.
    let mut specs = tenant_mix(StrategyKind::FinalAdrrOlc);
    for (spec, rate) in specs.iter_mut().zip([60.0, 50.0, 40.0, 30.0]) {
        spec.workload.rate_rps = rate;
    }
    let mut deferrals = 0u64;
    for seed in 0..3u64 {
        let serial = run_tenants_partitioned(&specs, &pool, seed, 1);
        let par = run_tenants_partitioned(&specs, &pool, seed, 4);
        let ctx = format!("boundary-exact, seed {seed}");
        assert_eq!(par.partition.partitions, 4, "{ctx}");
        assert_eq!(par.partition.lookahead_ms, 25.0, "{ctx}: σ=0 floor is exactly base_ms");
        deferrals += par.partition.boundary_deferrals;
        outputs_bitwise_equal(&par, &serial, &ctx);
    }
    assert!(
        deferrals > 0,
        "constant service under saturation must put events exactly on window boundaries"
    );
}

#[test]
fn zero_lookahead_falls_back_to_serial() {
    // `base_ms == 0` admits arbitrarily small service times: no positive
    // lookahead exists, the window protocol cannot run, and the executor
    // must fall back to the serial loop (flagged, still correct).
    let shard = ProviderCfg { base_ms: 0.0, ..ProviderCfg::default() };
    let pool = PoolCfg::split(shard, 2);
    let specs = tenant_mix(StrategyKind::AdaptiveDrr);
    let serial = run_tenants_partitioned(&specs, &pool, 7, 1);
    assert_eq!(
        serial.partition.serial_fallback,
        Some(FallbackReason::NotRequested),
        "serial was asked for, not forced"
    );
    let par = run_tenants_partitioned(&specs, &pool, 7, 4);
    assert_eq!(
        par.partition.serial_fallback,
        Some(FallbackReason::NoFloor),
        "zero lookahead must be rejected"
    );
    assert_eq!(par.partition.partitions, 1);
    assert_eq!(par.partition.lookahead_ms, 0.0);
    outputs_bitwise_equal(&par, &serial, "zero-lookahead fallback");
}

#[test]
fn empty_tenant_partitions_cleanly() {
    // A tenant with zero requests yields a partition whose event queue
    // starts empty — it must idle through the window protocol (no stall,
    // no spurious termination while siblings still have work).
    let mut specs = tenant_mix(StrategyKind::FinalAdrrOlc);
    specs[1].workload = WorkloadSpec::new(Mix::Balanced, 0, 5.0);
    let pool = PoolCfg::split(ProviderCfg::default(), 4);
    let serial = run_tenants_partitioned(&specs, &pool, 3, 1);
    assert!(serial.tenants[1].outcomes.is_empty(), "tenant 1 really offers nothing");
    let par = run_tenants_partitioned(&specs, &pool, 3, 4);
    assert_eq!(par.partition.partitions, 4);
    outputs_bitwise_equal(&par, &serial, "empty-tenant partition");
}

/// Assert two single-tenant outputs are bitwise identical: metrics (f64s
/// by bits), every outcome, and the engine diagnostics.
fn run_outputs_bitwise_equal(a: &RunOutput, b: &RunOutput, ctx: &str) {
    assert_eq!(a.metrics.n_offered, b.metrics.n_offered, "{ctx}");
    assert_eq!(a.metrics.n_completed, b.metrics.n_completed, "{ctx}");
    assert_eq!(a.metrics.n_rejected, b.metrics.n_rejected, "{ctx}");
    assert_eq!(a.metrics.n_timed_out, b.metrics.n_timed_out, "{ctx}");
    for (m, n) in [
        (a.metrics.short_p95_ms, b.metrics.short_p95_ms),
        (a.metrics.global_p95_ms, b.metrics.global_p95_ms),
        (a.metrics.global_std_ms, b.metrics.global_std_ms),
        (a.metrics.goodput_rps, b.metrics.goodput_rps),
        (a.metrics.makespan_ms, b.metrics.makespan_ms),
    ] {
        assert_eq!(m.to_bits(), n.to_bits(), "{ctx}: metric drift {m} vs {n}");
    }
    assert_eq!(a.outcomes.len(), b.outcomes.len(), "{ctx}");
    for (o, p) in a.outcomes.iter().zip(b.outcomes.iter()) {
        assert_eq!(o.status, p.status, "{ctx}: request {}", o.id);
        assert_eq!(
            o.latency_ms.map(f64::to_bits),
            p.latency_ms.map(f64::to_bits),
            "{ctx}: request {} latency bits",
            o.id
        );
        assert_eq!(o.defer_count, p.defer_count, "{ctx}: request {}", o.id);
    }
    let (da, db) = (&a.diagnostics, &b.diagnostics);
    assert_eq!(da.events_processed, db.events_processed, "{ctx}");
    assert_eq!(da.events_skipped, db.events_skipped, "{ctx}");
    assert_eq!(da.timers_canceled, db.timers_canceled, "{ctx}");
    assert_eq!(da.sends, db.sends, "{ctx}");
    assert_eq!(da.peak_provider_queue, db.peak_provider_queue, "{ctx}");
    assert_eq!(da.peak_inflight, db.peak_inflight, "{ctx}");
    assert_eq!(da.started_by_shard, db.started_by_shard, "{ctx}");
    assert_eq!(da.mean_queue_depth.to_bits(), db.mean_queue_depth.to_bits(), "{ctx}");
    assert_eq!(da.peak_queue_depth, db.peak_queue_depth, "{ctx}");
    assert_eq!(da.retries_scheduled, db.retries_scheduled, "{ctx}");
    assert_eq!(da.faulted_shard_ms.to_bits(), db.faulted_shard_ms.to_bits(), "{ctx}");
}

/// Run the same regime under the dynamic and static window bounds, assert
/// both are bit-identical to serial, and return `(dynamic, static)` window
/// counts for the regime-specific sizing assertion.
fn dynamic_vs_static_windows(
    specs: &[TenantSpec],
    pool: &PoolCfg,
    seed: u64,
    ctx: &str,
) -> (u64, u64) {
    let serial = run_tenants_partitioned(specs, pool, seed, 1);
    let dynamic = run_tenants_partitioned(specs, pool, seed, 4);
    assert!(dynamic.partition.serial_fallback.is_none(), "{ctx}");
    assert!(dynamic.partition.windows > 0, "{ctx}");
    outputs_bitwise_equal(&dynamic, &serial, &format!("{ctx}, dynamic bound"));
    let fixed = run_tenants_partitioned_with_bound(specs, pool, seed, 4, WindowBound::StaticFloor);
    assert!(fixed.partition.serial_fallback.is_none(), "{ctx}");
    outputs_bitwise_equal(&fixed, &serial, &format!("{ctx}, static bound"));
    (dynamic.partition.windows, fixed.partition.windows)
}

#[test]
fn congestion_slowdown_regime_needs_fewer_windows_than_static_floor() {
    // `slowdown_gamma > 0` is exactly where the static floor goes useless:
    // the floor stays `base_ms` forever while every actual service
    // stretches by the congestion curve. Naive tenants flood 2-slot shards,
    // so the pool saturates and the dynamic bound rides committed finish
    // times (~`base · slowdown`) instead of floor-sized steps.
    let shard = ProviderCfg {
        base_ms: 20.0,
        per_token_ms: 0.0,
        max_concurrency: 2,
        slowdown_gamma: 3.0,
        slowdown_exp: 1.5,
        slowdown_ref: 1.0,
        jitter_sigma: 0.0,
    };
    let pool = PoolCfg::split(shard, 2);
    let mut specs = tenant_mix(StrategyKind::DirectNaive);
    for (spec, rate) in specs.iter_mut().zip([120.0, 100.0, 80.0, 60.0]) {
        spec.workload.rate_rps = rate;
    }
    for seed in 0..2u64 {
        let ctx = format!("gamma regime, seed {seed}");
        let (dynamic, fixed) = dynamic_vs_static_windows(&specs, &pool, seed, &ctx);
        assert!(dynamic < fixed, "{ctx}: dynamic {dynamic} vs static {fixed} windows");
    }
}

#[test]
fn high_per_token_regime_needs_fewer_windows_than_static_floor() {
    // High `per_token_ms` opens a huge gap between the floor (`base_ms`,
    // tokens >= 0) and real services (hundreds of token-milliseconds), so
    // static windows advance by a sliver of any actual service time.
    let shard = ProviderCfg {
        base_ms: 5.0,
        per_token_ms: 2.0,
        max_concurrency: 2,
        slowdown_gamma: 0.0,
        slowdown_exp: 1.0,
        slowdown_ref: 8.0,
        jitter_sigma: 0.0,
    };
    let pool = PoolCfg::split(shard, 2);
    let mut specs = tenant_mix(StrategyKind::DirectNaive);
    for (spec, rate) in specs.iter_mut().zip([120.0, 100.0, 80.0, 60.0]) {
        spec.workload.rate_rps = rate;
    }
    for seed in 0..2u64 {
        let ctx = format!("per-token regime, seed {seed}");
        let (dynamic, fixed) = dynamic_vs_static_windows(&specs, &pool, seed, &ctx);
        assert!(dynamic < fixed, "{ctx}: dynamic {dynamic} vs static {fixed} windows");
    }
}

#[test]
fn extension_only_brownout_widens_windows_instead_of_forbidding_them() {
    // An extension-only brownout (factor < 1) keeps the fleet floor valid,
    // and the dynamic bound pushes each shard's floor through the fault
    // walk: inside the stall a floor's worth of work takes 1/factor as
    // long, so windows stretch across the brownout instead of tiling it in
    // floor-sized steps.
    let shard = ProviderCfg {
        base_ms: 25.0,
        per_token_ms: 0.0,
        max_concurrency: 4,
        slowdown_gamma: 0.0,
        slowdown_exp: 1.0,
        slowdown_ref: 8.0,
        jitter_sigma: 0.0,
    };
    let faults = FaultPlan::default()
        .brownout(0, 200.0, 1_400.0, 0.25)
        .unwrap()
        .brownout(1, 200.0, 1_400.0, 0.25)
        .unwrap();
    let pool = PoolCfg::split(shard, 2).with_faults(faults);
    let mut specs = tenant_mix(StrategyKind::FinalAdrrOlc);
    for (spec, rate) in specs.iter_mut().zip([60.0, 50.0, 40.0, 30.0]) {
        spec.workload.rate_rps = rate;
    }
    for seed in 0..2u64 {
        let ctx = format!("brownout regime, seed {seed}");
        let serial = run_tenants_partitioned(&specs, &pool, seed, 1);
        assert!(
            serial.diagnostics.faulted_shard_ms > 0.0,
            "{ctx}: the brownout must actually touch work"
        );
        let (dynamic, fixed) = dynamic_vs_static_windows(&specs, &pool, seed, &ctx);
        assert!(dynamic < fixed, "{ctx}: dynamic {dynamic} vs static {fixed} windows");
    }
}

fn run_single_tenant(strategy: StrategyKind, partitions: usize, seed: u64) -> RunOutput {
    let spec = WorkloadSpec::new(Mix::Balanced, 400, 120.0);
    let requests = spec.generate(seed);
    let mut src = LadderSource::new(InfoLevel::Coarse, Rng::new(seed).derive("priors"));
    let pool = PoolCfg::single(ProviderCfg { max_concurrency: 16, ..ProviderCfg::default() });
    run_pool_partitioned(
        &requests,
        &mut src,
        SchedulerCfg::for_strategy(strategy),
        &pool,
        seed,
        partitions,
    )
}

#[test]
fn single_tenant_request_range_carve_matches_serial_bit_for_bit() {
    // The second tentpole leg: a `run_pool` run has one tenant, so the
    // per-tenant carve degenerates — but a request-local stack (naive on
    // one shard) splits by contiguous request-id ranges instead, each
    // worker driving a private scheduler clone.
    for seed in 0..3u64 {
        let ctx = format!("single-tenant carve, seed {seed}");
        let serial = run_single_tenant(StrategyKind::DirectNaive, 1, seed);
        assert_eq!(
            serial.partition.serial_fallback,
            Some(FallbackReason::NotRequested),
            "{ctx}"
        );
        let par = run_single_tenant(StrategyKind::DirectNaive, 4, seed);
        assert_eq!(par.partition.partitions, 4, "{ctx}: the request carve must run");
        assert!(par.partition.serial_fallback.is_none(), "{ctx}");
        assert!(par.partition.windows > 0, "{ctx}");
        run_outputs_bitwise_equal(&par, &serial, &ctx);
    }
}

#[test]
fn stateful_single_tenant_stack_takes_the_flagged_fallback() {
    // A queueing stack keeps cross-request state (DRR deficits, ordering
    // indexes, pacing budgets), so carving its requests would change
    // decisions: the executor must refuse, flag why, and still be correct.
    let serial = run_single_tenant(StrategyKind::FinalAdrrOlc, 1, 5);
    let par = run_single_tenant(StrategyKind::FinalAdrrOlc, 4, 5);
    assert_eq!(par.partition.serial_fallback, Some(FallbackReason::StatefulCarve));
    assert_eq!(par.partition.partitions, 1);
    run_outputs_bitwise_equal(&par, &serial, "stateful single-tenant fallback");
}

#[test]
fn partition_count_is_capped_by_tenants_and_zero_means_auto() {
    let specs = tenant_mix(StrategyKind::DirectNaive);
    let pool = PoolCfg::split(ProviderCfg::default(), 4);
    let serial = run_tenants_partitioned(&specs, &pool, 11, 1);
    // More partitions than tenants: capped to one loop per tenant.
    let par = run_tenants_partitioned(&specs, &pool, 11, 64);
    assert_eq!(par.partition.partitions, specs.len(), "capped at tenant count");
    outputs_bitwise_equal(&par, &serial, "capped partitions");
    // 0 = one partition per core (whatever this machine has) — output
    // must be invariant to that machine-dependent choice.
    let auto = run_tenants_partitioned(&specs, &pool, 11, 0);
    assert!(auto.partition.partitions >= 1 && auto.partition.partitions <= specs.len());
    outputs_bitwise_equal(&auto, &serial, "auto partitions");
}
