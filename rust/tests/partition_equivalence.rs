//! The partitioned event loop's bit-compat contract: for any partition
//! count, [`run_tenants_partitioned`] must be **byte-identical** to the
//! serial reference loop (`partitions == 1`) — same per-tenant metric
//! bits, same per-request outcomes (status, latency bits, defer counts),
//! same engine diagnostics including the f64 queue-depth integral and the
//! per-shard start counts — across strategies × fleets × tenant mixes ×
//! seeds. This is the same bit-compat-ladder discipline as the 1-shard
//! and 1-tenant equivalences (`tests/pool_equivalence.rs`,
//! `tests/tenant_equivalence.rs`), one rung up.
//!
//! The release-mode leg of CI is load-bearing here: the window-boundary
//! shadow checks are `debug_assert!`s, so the release run proves the
//! protocol itself (not the asserts) carries the equality.

use blackbox_sched::predictor::InfoLevel;
use blackbox_sched::provider::pool::PoolCfg;
use blackbox_sched::provider::ProviderCfg;
use blackbox_sched::scheduler::{OrderingCfg, OrderingKind, SchedulerCfg, ShardPolicy, StrategyKind};
use blackbox_sched::sim::driver::{run_tenants_partitioned, MultiRunOutput, TenantSpec};
use blackbox_sched::workload::{Mix, WorkloadSpec};

/// Assert two multi-tenant outputs are bitwise identical: tenant metrics
/// (f64s compared by bits), every outcome, and the full diagnostics.
fn outputs_bitwise_equal(a: &MultiRunOutput, b: &MultiRunOutput, ctx: &str) {
    assert_eq!(a.tenants.len(), b.tenants.len(), "{ctx}");
    for (t, (x, y)) in a.tenants.iter().zip(b.tenants.iter()).enumerate() {
        assert_eq!(x.sends, y.sends, "{ctx}: tenant {t} sends");
        assert_eq!(x.metrics.n_offered, y.metrics.n_offered, "{ctx}: tenant {t}");
        assert_eq!(x.metrics.n_completed, y.metrics.n_completed, "{ctx}: tenant {t}");
        assert_eq!(x.metrics.n_rejected, y.metrics.n_rejected, "{ctx}: tenant {t}");
        assert_eq!(x.metrics.n_timed_out, y.metrics.n_timed_out, "{ctx}: tenant {t}");
        assert_eq!(x.metrics.defers_total, y.metrics.defers_total, "{ctx}: tenant {t}");
        assert_eq!(x.metrics.rejects_total, y.metrics.rejects_total, "{ctx}: tenant {t}");
        assert_eq!(x.metrics.defers_by_bucket, y.metrics.defers_by_bucket, "{ctx}: tenant {t}");
        assert_eq!(x.metrics.rejects_by_bucket, y.metrics.rejects_by_bucket, "{ctx}: tenant {t}");
        assert_eq!(
            x.metrics.feasibility_violations,
            y.metrics.feasibility_violations,
            "{ctx}: tenant {t}"
        );
        assert_eq!(x.metrics.completed_by_bucket, y.metrics.completed_by_bucket, "{ctx}: {t}");
        assert_eq!(x.metrics.offered_by_bucket, y.metrics.offered_by_bucket, "{ctx}: {t}");
        for (m, n) in [
            (x.metrics.short_p95_ms, y.metrics.short_p95_ms),
            (x.metrics.short_p90_ms, y.metrics.short_p90_ms),
            (x.metrics.global_p95_ms, y.metrics.global_p95_ms),
            (x.metrics.global_std_ms, y.metrics.global_std_ms),
            (x.metrics.heavy_p90_ms, y.metrics.heavy_p90_ms),
            (x.metrics.completion_rate, y.metrics.completion_rate),
            (x.metrics.satisfaction, y.metrics.satisfaction),
            (x.metrics.goodput_rps, y.metrics.goodput_rps),
            (x.metrics.makespan_ms, y.metrics.makespan_ms),
        ] {
            assert_eq!(m.to_bits(), n.to_bits(), "{ctx}: tenant {t} metric drift {m} vs {n}");
        }
        assert_eq!(x.outcomes.len(), y.outcomes.len(), "{ctx}: tenant {t}");
        for (o, p) in x.outcomes.iter().zip(y.outcomes.iter()) {
            assert_eq!(o.id, p.id, "{ctx}");
            assert_eq!(o.status, p.status, "{ctx}: request {}", o.id);
            assert_eq!(
                o.latency_ms.map(f64::to_bits),
                p.latency_ms.map(f64::to_bits),
                "{ctx}: request {} latency bits",
                o.id
            );
            assert_eq!(o.defer_count, p.defer_count, "{ctx}: request {}", o.id);
        }
    }
    let (da, db) = (&a.diagnostics, &b.diagnostics);
    assert_eq!(da.events_processed, db.events_processed, "{ctx}");
    assert_eq!(da.events_skipped, db.events_skipped, "{ctx}");
    assert_eq!(da.timers_canceled, db.timers_canceled, "{ctx}");
    assert_eq!(da.sends, db.sends, "{ctx}");
    assert_eq!(da.peak_provider_queue, db.peak_provider_queue, "{ctx}");
    assert_eq!(da.peak_inflight, db.peak_inflight, "{ctx}");
    assert_eq!(da.started_by_shard, db.started_by_shard, "{ctx}");
    assert_eq!(
        da.mean_queue_depth.to_bits(),
        db.mean_queue_depth.to_bits(),
        "{ctx}: depth integral drift {} vs {}",
        da.mean_queue_depth,
        db.mean_queue_depth
    );
    assert_eq!(da.peak_queue_depth, db.peak_queue_depth, "{ctx}");
    assert_eq!(da.ordering_select_work, db.ordering_select_work, "{ctx}");
    assert_eq!(da.ordering_group_count, db.ordering_group_count, "{ctx}");
    assert_eq!(da.ordering_scan_fallbacks, db.ordering_scan_fallbacks, "{ctx}");
}

/// A heterogeneous 4-tenant mix: different workloads, rates, request
/// counts, and shard policies, all on the given strategy.
fn tenant_mix(strategy: StrategyKind) -> Vec<TenantSpec> {
    let shapes = [
        (Mix::Balanced, 50usize, 9.0),
        (Mix::Heavy, 70, 6.0),
        (Mix::Balanced, 60, 12.0),
        (Mix::Heavy, 40, 4.0),
    ];
    shapes
        .iter()
        .enumerate()
        .map(|(t, &(mix, n, rate))| {
            let mut sched = SchedulerCfg::for_strategy(strategy);
            sched.shards.policy = ShardPolicy::ALL[t % ShardPolicy::ALL.len()];
            TenantSpec {
                workload: WorkloadSpec::new(mix, n, rate),
                sched,
                info: InfoLevel::Coarse,
                noise: 0.0,
            }
        })
        .collect()
}

#[test]
fn partitioned_matches_serial_bit_for_bit() {
    let fleets = [
        ("split4", PoolCfg::split(ProviderCfg::default(), 4)),
        ("hetero3", PoolCfg::heterogeneous(ProviderCfg::default(), 3, 0.4)),
    ];
    for seed in 0..3u64 {
        for (fleet_name, pool) in &fleets {
            for strategy in StrategyKind::ALL {
                let specs = tenant_mix(strategy);
                let serial = run_tenants_partitioned(&specs, pool, seed, 1);
                assert_eq!(serial.partition.partitions, 1);
                for partitions in [2usize, 3, 4] {
                    let ctx = format!("seed {seed}, {fleet_name}, {strategy:?}, P={partitions}");
                    let par = run_tenants_partitioned(&specs, pool, seed, partitions);
                    assert_eq!(
                        par.partition.partitions, partitions,
                        "{ctx}: the parallel path must actually run"
                    );
                    assert!(!par.partition.serial_fallback, "{ctx}");
                    assert!(par.partition.windows > 0, "{ctx}: windows advanced");
                    assert!(par.partition.lookahead_ms > 0.0, "{ctx}");
                    outputs_bitwise_equal(&par, &serial, &ctx);
                }
            }
        }
    }
}

#[test]
fn noisy_interval_tenants_partition_bit_for_bit() {
    // Continuous noisy priors plus the full uncertainty stack — robust-SJF
    // width demotion, quantized feasible-set grouping, and the online
    // recalibrator — through the partitioned loop. Each tenant's noise
    // stream derives from its own tenant seed, so injection must be
    // byte-identical no matter how tenants are carved across partition
    // threads.
    let mut specs = tenant_mix(StrategyKind::AdaptiveDrr);
    for (t, spec) in specs.iter_mut().enumerate() {
        spec.noise = [0.4, 0.2, 0.4, 0.0][t];
        spec.sched.recalibrate = t % 2 == 0;
    }
    specs[0].sched.heavy_ordering = OrderingKind::RobustSjf;
    specs[1].sched.heavy_ordering = OrderingKind::FeasibleSet;
    specs[1].sched.ordering = OrderingCfg::quantized();
    specs[2].sched.heavy_ordering = OrderingKind::Sjf;
    let pool = PoolCfg::split(ProviderCfg::default(), 3);
    for seed in 0..3u64 {
        let serial = run_tenants_partitioned(&specs, &pool, seed, 1);
        for partitions in [2usize, 4] {
            let ctx = format!("noisy tenants, seed {seed}, P={partitions}");
            let par = run_tenants_partitioned(&specs, &pool, seed, partitions);
            assert!(!par.partition.serial_fallback, "{ctx}");
            outputs_bitwise_equal(&par, &serial, &ctx);
        }
    }
}

#[test]
fn boundary_exact_events_defer_and_still_match() {
    // Deterministic service physics: no jitter, no per-token cost, no
    // congestion slowdown, so *every* service time is exactly `base_ms`
    // and the lookahead window is exactly `base_ms` wide. A submission at
    // a window's start then completes exactly on its window end — the
    // strict `t < end` rule must defer it to the next window, and the
    // merged result must still be bit-identical to serial.
    let shard = ProviderCfg {
        base_ms: 25.0,
        per_token_ms: 0.0,
        jitter_sigma: 0.0,
        slowdown_gamma: 0.0,
        max_concurrency: 4,
        ..ProviderCfg::default()
    };
    let pool = PoolCfg::split(shard, 2);
    // Saturate the 4 service slots (~180 rps against 25 ms services) so
    // queued submissions chain off completions: every chained start lands
    // on a `t0 + 25k` lattice shared across partitions through the common
    // pool, which is what manufactures exact peek == window-end hits.
    let mut specs = tenant_mix(StrategyKind::FinalAdrrOlc);
    for (spec, rate) in specs.iter_mut().zip([60.0, 50.0, 40.0, 30.0]) {
        spec.workload.rate_rps = rate;
    }
    let mut deferrals = 0u64;
    for seed in 0..3u64 {
        let serial = run_tenants_partitioned(&specs, &pool, seed, 1);
        let par = run_tenants_partitioned(&specs, &pool, seed, 4);
        let ctx = format!("boundary-exact, seed {seed}");
        assert_eq!(par.partition.partitions, 4, "{ctx}");
        assert_eq!(par.partition.lookahead_ms, 25.0, "{ctx}: σ=0 floor is exactly base_ms");
        deferrals += par.partition.boundary_deferrals;
        outputs_bitwise_equal(&par, &serial, &ctx);
    }
    assert!(
        deferrals > 0,
        "constant service under saturation must put events exactly on window boundaries"
    );
}

#[test]
fn zero_lookahead_falls_back_to_serial() {
    // `base_ms == 0` admits arbitrarily small service times: no positive
    // lookahead exists, the window protocol cannot run, and the executor
    // must fall back to the serial loop (flagged, still correct).
    let shard = ProviderCfg { base_ms: 0.0, ..ProviderCfg::default() };
    let pool = PoolCfg::split(shard, 2);
    let specs = tenant_mix(StrategyKind::AdaptiveDrr);
    let serial = run_tenants_partitioned(&specs, &pool, 7, 1);
    assert!(!serial.partition.serial_fallback, "serial was asked for, not forced");
    let par = run_tenants_partitioned(&specs, &pool, 7, 4);
    assert!(par.partition.serial_fallback, "zero lookahead must be rejected");
    assert_eq!(par.partition.partitions, 1);
    assert_eq!(par.partition.lookahead_ms, 0.0);
    outputs_bitwise_equal(&par, &serial, "zero-lookahead fallback");
}

#[test]
fn empty_tenant_partitions_cleanly() {
    // A tenant with zero requests yields a partition whose event queue
    // starts empty — it must idle through the window protocol (no stall,
    // no spurious termination while siblings still have work).
    let mut specs = tenant_mix(StrategyKind::FinalAdrrOlc);
    specs[1].workload = WorkloadSpec::new(Mix::Balanced, 0, 5.0);
    let pool = PoolCfg::split(ProviderCfg::default(), 4);
    let serial = run_tenants_partitioned(&specs, &pool, 3, 1);
    assert!(serial.tenants[1].outcomes.is_empty(), "tenant 1 really offers nothing");
    let par = run_tenants_partitioned(&specs, &pool, 3, 4);
    assert_eq!(par.partition.partitions, 4);
    outputs_bitwise_equal(&par, &serial, "empty-tenant partition");
}

#[test]
fn partition_count_is_capped_by_tenants_and_zero_means_auto() {
    let specs = tenant_mix(StrategyKind::DirectNaive);
    let pool = PoolCfg::split(ProviderCfg::default(), 4);
    let serial = run_tenants_partitioned(&specs, &pool, 11, 1);
    // More partitions than tenants: capped to one loop per tenant.
    let par = run_tenants_partitioned(&specs, &pool, 11, 64);
    assert_eq!(par.partition.partitions, specs.len(), "capped at tenant count");
    outputs_bitwise_equal(&par, &serial, "capped partitions");
    // 0 = one partition per core (whatever this machine has) — output
    // must be invariant to that machine-dependent choice.
    let auto = run_tenants_partitioned(&specs, &pool, 11, 0);
    assert!(auto.partition.partitions >= 1 && auto.partition.partitions <= specs.len());
    outputs_bitwise_equal(&auto, &serial, "auto partitions");
}
