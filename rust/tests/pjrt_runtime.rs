//! Integration: the PJRT runtime path — artifact load, golden numerics,
//! batched prediction, and a full simulated run with the neural prior
//! source on the admission path. Compiled under the `pjrt` feature (the
//! default build ships a stub runtime without the xla bindings); CI's
//! `--features pjrt` matrix leg builds this file against the vendored xla
//! API stub (vendor/xla). Within that, tests skip (with a notice) when
//! artifacts have not been built or when only the API stub is linked:
//! `make artifacts && cargo test --features pjrt` against the real
//! bindings exercises everything.

#![cfg(feature = "pjrt")]

use blackbox_sched::core::TokenBucket;
use blackbox_sched::predictor::features::batch_features;
use blackbox_sched::predictor::PriorSource;
use blackbox_sched::provider::ProviderCfg;
use blackbox_sched::runtime::{artifacts_available, default_artifacts_dir, NnPriorSource, Predictor};
use blackbox_sched::scheduler::{SchedulerCfg, StrategyKind};
use blackbox_sched::sim::driver;
use blackbox_sched::workload::{Mix, WorkloadSpec};

fn predictor() -> Option<Predictor> {
    let dir = default_artifacts_dir();
    if !artifacts_available(&dir) {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    match Predictor::load(&dir) {
        Ok(p) => Some(p),
        Err(e) => {
            // The vendored xla API stub type-checks this whole path but
            // cannot execute HLO — that (and only that) failure is a skip.
            // With real bindings linked, a load failure with artifacts
            // present is a genuine artifact problem and must stay fatal.
            let chain = format!("{e:#}");
            assert!(
                chain.contains("vendored xla stub"),
                "artifacts present but unloadable: {chain}"
            );
            eprintln!("SKIP: PJRT runtime is the vendored API stub ({chain})");
            None
        }
    }
}

#[test]
fn golden_vectors_match_python_reference() {
    let Some(p) = predictor() else { return };
    let g = &p.meta.golden;
    let n = g.features.len();
    let feats: Vec<f32> = g.features.iter().flatten().copied().collect();
    let priors = p.predict(&feats, n).unwrap();
    for i in 0..n {
        let rel50 = ((priors[i].p50 - g.expected_p50[i]) / g.expected_p50[i]).abs();
        let rel90 = ((priors[i].p90 - g.expected_p90[i]) / g.expected_p90[i]).abs();
        assert!(rel50 < 1e-3 && rel90 < 1e-3, "row {i}: rel50={rel50} rel90={rel90}");
        assert!(priors[i].p90 >= priors[i].p50, "monotone quantiles");
    }
}

#[test]
fn batch_and_single_paths_agree() {
    let Some(p) = predictor() else { return };
    let reqs = WorkloadSpec::new(Mix::Balanced, 300, 50.0).generate(3);
    let refs: Vec<&blackbox_sched::Request> = reqs.iter().collect();
    // Bulk (chunked over b512/b128 executables)…
    let feats: Vec<f32> = refs.iter().flat_map(|r| blackbox_sched::predictor::features::features(r)).collect();
    let bulk = p.predict(&feats, refs.len()).unwrap();
    // …vs singles (padded b128 path).
    for (i, r) in refs.iter().enumerate().step_by(37) {
        let f1 = batch_features(&[*r], 1);
        let single = p.predict(&f1, 1).unwrap()[0];
        assert!(
            (single.p50 - bulk[i].p50).abs() < 1e-3 * bulk[i].p50.max(1.0),
            "row {i}: {} vs {}",
            single.p50,
            bulk[i].p50
        );
    }
}

#[test]
fn predictor_is_informative_about_buckets() {
    // The trained model must separate cheap from expensive work — the whole
    // premise. Check rank correlation on fresh synthetic requests.
    let Some(p) = predictor() else { return };
    let reqs = WorkloadSpec::new(Mix::Balanced, 1000, 50.0).generate(11);
    let refs: Vec<&blackbox_sched::Request> = reqs.iter().collect();
    let feats: Vec<f32> =
        refs.iter().flat_map(|r| blackbox_sched::predictor::features::features(r)).collect();
    let priors = p.predict(&feats, refs.len()).unwrap();
    // Mean predicted p50 must be monotone in the true bucket.
    let mut sums = [0.0f64; 4];
    let mut counts = [0usize; 4];
    for (r, prior) in refs.iter().zip(&priors) {
        sums[r.true_bucket.index()] += prior.p50;
        counts[r.true_bucket.index()] += 1;
    }
    let means: Vec<f64> =
        (0..4).map(|i| sums[i] / counts[i].max(1) as f64).collect();
    assert!(
        means[0] < means[1] && means[1] < means[2] && means[2] < means[3],
        "bucket-mean p50 not monotone: {means:?}"
    );
    // p90 over-coverage: most true counts fall below the p90 estimate
    // (trained to 0.9; tolerate sampling slack).
    let covered = refs
        .iter()
        .zip(&priors)
        .filter(|(r, prior)| (r.true_output_tokens as f64) <= prior.p90)
        .count();
    let frac = covered as f64 / refs.len() as f64;
    assert!(frac > 0.8, "p90 coverage {frac}");
}

#[test]
fn full_run_with_neural_priors_on_admission_path() {
    let Some(p) = predictor() else { return };
    let mut nn = NnPriorSource::new(p);
    let requests = WorkloadSpec::new(Mix::Heavy, 120, 14.0).generate(5);
    let out = driver::run(
        &requests,
        &mut nn,
        SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc),
        ProviderCfg::default(),
        5,
    );
    assert_eq!(out.metrics.n_offered, 120);
    assert!(out.metrics.completion_rate > 0.9, "cr={}", out.metrics.completion_rate);
    assert!(out.metrics.short_p95_ms < 1_000.0, "short tail {}", out.metrics.short_p95_ms);
    // The neural route must never reject what it believes is short.
    assert_eq!(out.metrics.rejects_by_bucket[TokenBucket::Short.index()], 0);
    assert_eq!(nn.calls(), 120, "one PJRT call per admission");
}

#[test]
fn meta_constants_guard_is_enforced() {
    let Some(p) = predictor() else { return };
    // check_constants already ran inside load; assert the metadata reports
    // the calibrated training quality we ship with.
    assert!(p.meta.training_coverage_p90 > 0.8 && p.meta.training_coverage_p90 <= 1.0);
    assert_eq!(p.meta.batch_sizes, vec![128, 512]);
}
