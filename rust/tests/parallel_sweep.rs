//! Integration: the deterministic-parallelism contract. `ParallelSweep`
//! must reproduce serial `run_cell` output bit-for-bit — same cells, same
//! order, same float bits — for any worker count, because the experiment
//! CSVs are required to be byte-identical between `--jobs 1` and
//! `--jobs N` (CI diffs them on every run).

use blackbox_sched::experiments::runner::{run_cell, CellSpec, Congestion, ParallelSweep, Regime};
use blackbox_sched::metrics::RunMetrics;
use blackbox_sched::scheduler::{OrderingKind, SchedulerCfg, StrategyKind};
use blackbox_sched::util::pool;
use blackbox_sched::workload::Mix;

fn grid_2x2() -> Vec<CellSpec> {
    let regimes = [
        Regime { mix: Mix::Balanced, congestion: Congestion::High },
        Regime { mix: Mix::Heavy, congestion: Congestion::Medium },
    ];
    let strategies = [StrategyKind::QuotaTiered, StrategyKind::FinalAdrrOlc];
    let mut specs = Vec::new();
    for regime in regimes {
        for strategy in strategies {
            specs.push(CellSpec::new(regime, SchedulerCfg::for_strategy(strategy), 40));
        }
    }
    specs
}

fn assert_metrics_identical(a: &RunMetrics, b: &RunMetrics, ctx: &str) {
    assert_eq!(a.n_offered, b.n_offered, "{ctx}");
    assert_eq!(a.n_completed, b.n_completed, "{ctx}");
    assert_eq!(a.n_rejected, b.n_rejected, "{ctx}");
    assert_eq!(a.n_timed_out, b.n_timed_out, "{ctx}");
    assert_eq!(a.defers_total, b.defers_total, "{ctx}");
    assert_eq!(a.rejects_total, b.rejects_total, "{ctx}");
    assert_eq!(a.defers_by_bucket, b.defers_by_bucket, "{ctx}");
    assert_eq!(a.rejects_by_bucket, b.rejects_by_bucket, "{ctx}");
    assert_eq!(a.completed_by_bucket, b.completed_by_bucket, "{ctx}");
    assert_eq!(a.feasibility_violations, b.feasibility_violations, "{ctx}");
    for (name, x, y) in [
        ("short_p95_ms", a.short_p95_ms, b.short_p95_ms),
        ("short_p90_ms", a.short_p90_ms, b.short_p90_ms),
        ("global_p95_ms", a.global_p95_ms, b.global_p95_ms),
        ("global_std_ms", a.global_std_ms, b.global_std_ms),
        ("heavy_p90_ms", a.heavy_p90_ms, b.heavy_p90_ms),
        ("completion_rate", a.completion_rate, b.completion_rate),
        ("satisfaction", a.satisfaction, b.satisfaction),
        ("goodput_rps", a.goodput_rps, b.goodput_rps),
        ("makespan_ms", a.makespan_ms, b.makespan_ms),
    ] {
        // Bit comparison is NaN-safe and catches any cross-thread float
        // drift that a tolerance compare would mask.
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: {name} {x} vs {y}");
    }
}

#[test]
fn sweep_is_bit_identical_to_serial_for_2x2x3_grid() {
    let specs = grid_2x2();
    let serial: Vec<Vec<RunMetrics>> = specs.iter().map(|s| run_cell(s, 3)).collect();
    for jobs in [1usize, 2, 3, 4, 8] {
        let par = ParallelSweep::new(jobs).run_cells(&specs, 3);
        assert_eq!(par.len(), serial.len());
        for (cell, (pc, sc)) in par.iter().zip(&serial).enumerate() {
            assert_eq!(pc.len(), sc.len(), "cell {cell}");
            for (seed, (a, b)) in pc.iter().zip(sc).enumerate() {
                assert_metrics_identical(a, b, &format!("jobs={jobs} cell={cell} seed={seed}"));
            }
        }
    }
}

#[test]
fn sweep_is_bit_identical_with_noisy_interval_priors() {
    // The noise wrapper's RNG stream derives from the (cell, seed) pair
    // inside the job, so injection — and the recalibrator feedback it
    // drives — must not depend on which worker runs the cell.
    let regime = Regime { mix: Mix::Balanced, congestion: Congestion::High };
    let mut specs = Vec::new();
    for strategy in [StrategyKind::AdaptiveDrr, StrategyKind::FinalAdrrOlc] {
        let mut sched = SchedulerCfg::for_strategy(strategy);
        sched.heavy_ordering = OrderingKind::RobustSjf;
        sched.recalibrate = true;
        specs.push(CellSpec::new(regime, sched, 40).with_noise(0.4));
    }
    let serial: Vec<Vec<RunMetrics>> = specs.iter().map(|s| run_cell(s, 3)).collect();
    for jobs in [1usize, 4] {
        let par = ParallelSweep::new(jobs).run_cells(&specs, 3);
        assert_eq!(par.len(), serial.len());
        for (cell, (pc, sc)) in par.iter().zip(&serial).enumerate() {
            for (seed, (a, b)) in pc.iter().zip(sc).enumerate() {
                assert_metrics_identical(
                    a,
                    b,
                    &format!("noisy jobs={jobs} cell={cell} seed={seed}"),
                );
            }
        }
    }
}

#[test]
fn sweep_preserves_paired_comparison_across_policies() {
    // The controlled-evaluation requirement survives parallel execution:
    // per-seed offered-by-bucket tables are identical across policies in
    // the same regime, because every job regenerates its workload from the
    // (regime, seed) pair alone.
    let specs = grid_2x2();
    let runs = ParallelSweep::new(4).run_cells(&specs, 3);
    // Cells 0 and 1 share a regime; so do cells 2 and 3.
    for pair in [(0usize, 1usize), (2, 3)] {
        for seed in 0..3 {
            assert_eq!(
                runs[pair.0][seed].offered_by_bucket,
                runs[pair.1][seed].offered_by_bucket,
                "policies in one regime must see identical per-seed workloads"
            );
        }
    }
}

#[test]
fn arrival_shims_match_specs_bitwise() {
    // The `ArrivalSpec` redesign keeps the historic builder shims as thin
    // wrappers: `WorkloadSpec::bursty(..)` must hand the generator exactly
    // the state the declarative spec does, so the workloads — and every
    // CSV derived from them — stay byte-identical across the API change.
    use blackbox_sched::workload::{ArrivalSpec, WorkloadSpec};
    for seed in [0u64, 7, 1234] {
        let shim = WorkloadSpec::new(Mix::Heavy, 80, 14.0).bursty(4.0, 2_000.0).generate(seed);
        let spec = WorkloadSpec::new(Mix::Heavy, 80, 14.0)
            .with_arrivals(ArrivalSpec::Bursty { burst_factor: 4.0, mean_phase_ms: 2_000.0 })
            .generate(seed);
        assert_eq!(shim.len(), spec.len());
        for (a, b) in shim.iter().zip(&spec) {
            assert_eq!(a.id, b.id, "seed {seed}");
            assert_eq!(a.arrival_ms.to_bits(), b.arrival_ms.to_bits(), "seed {seed}");
            assert_eq!(a.prompt_tokens, b.prompt_tokens, "seed {seed}");
            assert_eq!(a.max_tokens, b.max_tokens, "seed {seed}");
            assert_eq!(a.deadline_ms.to_bits(), b.deadline_ms.to_bits(), "seed {seed}");
            assert_eq!(a.timeout_ms.to_bits(), b.timeout_ms.to_bits(), "seed {seed}");
            assert_eq!(a.true_output_tokens, b.true_output_tokens, "seed {seed}");
        }
    }
}

#[test]
fn storm_cells_are_bit_identical_across_partitions() {
    // The storms grid rides the `--partitions` CI diff: an extension-only
    // fault plan plus armed client retries must not perturb a single bit
    // between the serial loop and the partitioned executor.
    use blackbox_sched::predictor::InfoLevel;
    use blackbox_sched::provider::fault::FaultPlan;
    use blackbox_sched::provider::pool::PoolCfg;
    use blackbox_sched::provider::ProviderCfg;
    use blackbox_sched::scheduler::{RetryCfg, ShardPolicy};
    use blackbox_sched::sim::driver::{self, TenantSpec};
    use blackbox_sched::workload::{ArrivalSpec, WorkloadSpec};

    let mut sched = SchedulerCfg::for_strategy(StrategyKind::AdaptiveDrr);
    sched.shards.policy = ShardPolicy::LeastInflight;
    sched.shards.failover = true;
    sched.retry = RetryCfg::new(3, 250.0, 2_000.0);
    let tenants: Vec<TenantSpec> = (0..4)
        .map(|_| TenantSpec {
            workload: WorkloadSpec::new(Mix::Balanced, 30, 5.0).with_arrivals(
                ArrivalSpec::FlashCrowd { spike_factor: 8.0, every_ms: 30_000.0, spike_ms: 2_000.0 },
            ),
            sched: sched.clone(),
            info: InfoLevel::Coarse,
            noise: 0.0,
        })
        .collect();
    let pool = PoolCfg::split(ProviderCfg::default(), 2).with_faults(
        FaultPlan::default().brownout(0, 1_000.0, 20_000.0, 0.4).expect("valid plan"),
    );
    let serial = driver::run_tenants_partitioned(&tenants, &pool, 5, 1);
    let par = driver::run_tenants_partitioned(&tenants, &pool, 5, 4);
    assert_eq!(serial.diagnostics.retries_scheduled, par.diagnostics.retries_scheduled);
    assert_eq!(
        serial.diagnostics.faulted_shard_ms.to_bits(),
        par.diagnostics.faulted_shard_ms.to_bits()
    );
    assert!(serial.diagnostics.faulted_shard_ms > 0.0, "the brownout must bite");
    for (t, (a, b)) in serial.tenants.iter().zip(&par.tenants).enumerate() {
        assert_eq!(a.sends, b.sends, "tenant {t}");
        assert_metrics_identical(&a.metrics, &b.metrics, &format!("storm tenant {t}"));
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(x.status, y.status, "tenant {t} req {}", x.id);
            assert_eq!(
                x.latency_ms.map(f64::to_bits),
                y.latency_ms.map(f64::to_bits),
                "tenant {t} req {}",
                x.id
            );
        }
    }
}

#[test]
fn pool_default_jobs_reflects_cores() {
    // Smoke check that the default worker count is sane on this host.
    let jobs = pool::default_jobs();
    assert!(jobs >= 1 && jobs <= 4096);
}
