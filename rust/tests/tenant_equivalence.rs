//! The multi-tenant driver's bit-compat contract: a 1-tenant
//! [`run_tenants`] run consumes the base RNG streams verbatim and shares
//! `run_pool`'s event loop, so its output is **byte-identical** — same
//! metrics bits, same per-request outcomes, same engine diagnostics —
//! across strategies, fleet shapes, and seeds. Multi-tenant runs must stay
//! deterministic and conserving, and tenant workload streams must be
//! independent of tenant count.
//!
//! (The `tenants` experiment's `--jobs` invariance is covered by the CI
//! determinism diff, which re-runs the whole `exp all` battery at two
//! worker counts.)

use blackbox_sched::metrics::RunMetrics;
use blackbox_sched::predictor::{InfoLevel, LadderSource};
use blackbox_sched::provider::pool::PoolCfg;
use blackbox_sched::provider::ProviderCfg;
use blackbox_sched::scheduler::{SchedulerCfg, ShardPolicy, StrategyKind};
use blackbox_sched::sim::driver::{run_pool, run_tenants, tenant_seed, RunOutput, TenantSpec};
use blackbox_sched::util::rng::Rng;
use blackbox_sched::workload::{Mix, WorkloadSpec};

fn metrics_bitwise_equal(a: &RunMetrics, b: &RunMetrics, ctx: &str) {
    assert_eq!(a.n_offered, b.n_offered, "{ctx}");
    assert_eq!(a.n_completed, b.n_completed, "{ctx}");
    assert_eq!(a.n_rejected, b.n_rejected, "{ctx}");
    assert_eq!(a.n_timed_out, b.n_timed_out, "{ctx}");
    assert_eq!(a.defers_total, b.defers_total, "{ctx}");
    assert_eq!(a.rejects_total, b.rejects_total, "{ctx}");
    assert_eq!(a.defers_by_bucket, b.defers_by_bucket, "{ctx}");
    assert_eq!(a.rejects_by_bucket, b.rejects_by_bucket, "{ctx}");
    assert_eq!(a.feasibility_violations, b.feasibility_violations, "{ctx}");
    for (x, y) in [
        (a.short_p95_ms, b.short_p95_ms),
        (a.short_p90_ms, b.short_p90_ms),
        (a.global_p95_ms, b.global_p95_ms),
        (a.global_std_ms, b.global_std_ms),
        (a.heavy_p90_ms, b.heavy_p90_ms),
        (a.completion_rate, b.completion_rate),
        (a.satisfaction, b.satisfaction),
        (a.goodput_rps, b.goodput_rps),
        (a.makespan_ms, b.makespan_ms),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: float drift {x} vs {y}");
    }
}

/// The reference side: `run_pool` with the exact stream conventions
/// `run_tenants` applies to tenant 0.
fn reference_run(
    spec: &WorkloadSpec,
    strategy: StrategyKind,
    policy: ShardPolicy,
    pool: &PoolCfg,
    seed: u64,
) -> RunOutput {
    let requests = spec.generate(seed);
    let mut src =
        LadderSource::new(InfoLevel::Coarse, Rng::new(seed ^ 0x5EED_50_u64).derive("priors"));
    let mut cfg = SchedulerCfg::for_strategy(strategy);
    cfg.shards.policy = policy;
    run_pool(&requests, &mut src, cfg, pool, seed)
}

#[test]
fn one_tenant_matches_run_pool_byte_for_byte() {
    let pools = [
        ("single", PoolCfg::single(ProviderCfg::default())),
        ("split4", PoolCfg::split(ProviderCfg::default(), 4)),
        ("hetero3", PoolCfg::heterogeneous(ProviderCfg::default(), 3, 0.4)),
    ];
    let strategies =
        [StrategyKind::FinalAdrrOlc, StrategyKind::DirectNaive, StrategyKind::AdaptiveDrr];
    for seed in 0..3u64 {
        for (pool_name, pool) in &pools {
            for &strategy in &strategies {
                for policy in ShardPolicy::ALL {
                    let ctx = format!("seed {seed}, {pool_name}, {strategy:?}, {policy:?}");
                    let spec = WorkloadSpec::new(Mix::Balanced, 60, 14.0);
                    let base = reference_run(&spec, strategy, policy, pool, seed);
                    let mut sched = SchedulerCfg::for_strategy(strategy);
                    sched.shards.policy = policy;
                    let multi = run_tenants(
                        &[TenantSpec {
                            workload: spec.clone(),
                            sched,
                            info: InfoLevel::Coarse,
                            noise: 0.0,
                        }],
                        pool,
                        seed,
                    );
                    assert_eq!(multi.tenants.len(), 1, "{ctx}");
                    let t0 = &multi.tenants[0];
                    metrics_bitwise_equal(&t0.metrics, &base.metrics, &ctx);
                    assert_eq!(t0.outcomes.len(), base.outcomes.len(), "{ctx}");
                    for (x, y) in t0.outcomes.iter().zip(base.outcomes.iter()) {
                        assert_eq!(x.id, y.id, "{ctx}");
                        assert_eq!(x.status, y.status, "{ctx}");
                        assert_eq!(
                            x.latency_ms.map(f64::to_bits),
                            y.latency_ms.map(f64::to_bits),
                            "{ctx}: latency bits must match"
                        );
                        assert_eq!(x.defer_count, y.defer_count, "{ctx}");
                    }
                    let da = &multi.diagnostics;
                    let db = &base.diagnostics;
                    assert_eq!(da.events_processed, db.events_processed, "{ctx}");
                    assert_eq!(da.events_skipped, db.events_skipped, "{ctx}");
                    assert_eq!(da.timers_canceled, db.timers_canceled, "{ctx}");
                    assert_eq!(da.sends, db.sends, "{ctx}");
                    assert_eq!(da.peak_provider_queue, db.peak_provider_queue, "{ctx}");
                    assert_eq!(da.peak_inflight, db.peak_inflight, "{ctx}");
                    assert_eq!(da.started_by_shard, db.started_by_shard, "{ctx}");
                    assert_eq!(t0.sends, db.sends, "{ctx}");
                }
            }
        }
    }
}

#[test]
fn multi_tenant_runs_are_bitwise_reproducible() {
    let specs: Vec<TenantSpec> = vec![
        TenantSpec {
            workload: WorkloadSpec::new(Mix::Balanced, 50, 8.0),
            sched: SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc),
            info: InfoLevel::Coarse,
            noise: 0.0,
        },
        TenantSpec {
            workload: WorkloadSpec::new(Mix::Heavy, 40, 6.0),
            sched: SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc),
            info: InfoLevel::Oracle,
            noise: 0.0,
        },
        TenantSpec {
            workload: WorkloadSpec::new(Mix::Balanced, 30, 4.0),
            sched: SchedulerCfg::for_strategy(StrategyKind::QuotaTiered),
            info: InfoLevel::Coarse,
            noise: 0.0,
        },
    ];
    for pool in [
        PoolCfg::single(ProviderCfg::default()),
        PoolCfg::heterogeneous(ProviderCfg::default(), 4, 0.5),
    ] {
        let a = run_tenants(&specs, &pool, 11);
        let b = run_tenants(&specs, &pool, 11);
        for (t, (ta, tb)) in a.tenants.iter().zip(b.tenants.iter()).enumerate() {
            metrics_bitwise_equal(&ta.metrics, &tb.metrics, &format!("tenant {t}"));
            for (x, y) in ta.outcomes.iter().zip(tb.outcomes.iter()) {
                assert_eq!(x.status, y.status);
                assert_eq!(x.latency_ms.map(f64::to_bits), y.latency_ms.map(f64::to_bits));
            }
        }
        assert_eq!(a.diagnostics.events_processed, b.diagnostics.events_processed);
        // Conservation across the fleet.
        assert_eq!(a.tenants.iter().map(|t| t.sends).sum::<u64>(), a.diagnostics.sends);
        assert_eq!(
            a.diagnostics.started_by_shard.iter().sum::<u64>(),
            a.diagnostics.sends,
            "every send eventually starts on some shard"
        );
    }
}

#[test]
fn adding_a_tenant_does_not_perturb_tenant_workload_streams() {
    // Tenant t's request table depends only on (run seed, t) — never on how
    // many neighbors share the fleet. (Outcomes DO change — interference
    // through the shared pool is the phenomenon under study — but offered
    // work must not.)
    for t in 0..4usize {
        let spec = WorkloadSpec::new(Mix::Balanced, 25, 5.0);
        let a = spec.generate(tenant_seed(7, t));
        let b = spec.generate(tenant_seed(7, t));
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.arrival_ms, y.arrival_ms);
            assert_eq!(x.true_output_tokens, y.true_output_tokens);
        }
    }
    // Distinct tenants draw distinct streams.
    let seeds: Vec<u64> = (0..4).map(|t| tenant_seed(7, t)).collect();
    for i in 0..seeds.len() {
        for j in (i + 1)..seeds.len() {
            assert_ne!(seeds[i], seeds[j], "tenants {i} and {j} share a stream");
        }
    }
}

#[test]
fn heavy_tenant_interferes_through_the_shared_pool() {
    // Physics sanity: a heavy neighbor at the same rate share must not
    // *improve* the standard tenant's tail vs a balanced neighbor, and the
    // run must stay conserving. (Direction-only check: exact magnitudes are
    // seed-dependent.)
    let mk = |mix: Mix| TenantSpec {
        workload: WorkloadSpec::new(mix, 60, 8.0),
        sched: SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc),
        info: InfoLevel::Coarse,
        noise: 0.0,
    };
    let pool = PoolCfg::single(ProviderCfg::default());
    let calm = run_tenants(&[mk(Mix::Balanced), mk(Mix::Balanced)], &pool, 2);
    let noisy = run_tenants(&[mk(Mix::Balanced), mk(Mix::Heavy)], &pool, 2);
    // Tenant 0's own workload stream is identical in both runs (same seed,
    // same spec); only the neighbor changed.
    let calm_t0 = &calm.tenants[0].metrics;
    let noisy_t0 = &noisy.tenants[0].metrics;
    assert_eq!(calm_t0.n_offered, noisy_t0.n_offered);
    assert!(
        noisy_t0.global_p95_ms >= calm_t0.global_p95_ms * 0.5,
        "heavy neighbor should not magically improve the tail: {} vs {}",
        noisy_t0.global_p95_ms,
        calm_t0.global_p95_ms
    );
}
