//! Integration: every strategy, end to end, on the DES driver — lifecycle
//! invariants, determinism, and the black-box constraint.

use blackbox_sched::core::{RequestStatus, TokenBucket};
use blackbox_sched::predictor::{InfoLevel, LadderSource};
use blackbox_sched::provider::ProviderCfg;
use blackbox_sched::scheduler::{SchedulerCfg, StrategyKind};
use blackbox_sched::sim::driver::{run, RunOutput};
use blackbox_sched::util::rng::Rng;
use blackbox_sched::workload::{Mix, WorkloadSpec};

const ALL_STRATEGIES: [StrategyKind; 8] = StrategyKind::ALL;

fn run_one(strategy: StrategyKind, mix: Mix, rate: f64, n: usize, seed: u64) -> RunOutput {
    let requests = WorkloadSpec::new(mix, n, rate).generate(seed);
    let mut src = LadderSource::new(InfoLevel::Coarse, Rng::new(seed).derive("priors"));
    run(&requests, &mut src, SchedulerCfg::for_strategy(strategy), ProviderCfg::default(), seed)
}

#[test]
fn every_strategy_terminates_every_request() {
    for strategy in ALL_STRATEGIES {
        for (mix, rate) in [(Mix::Balanced, 20.0), (Mix::Heavy, 14.0), (Mix::ShareGpt, 20.0)] {
            let out = run_one(strategy, mix, rate, 150, 42);
            assert_eq!(out.metrics.n_offered, 150);
            for o in &out.outcomes {
                assert!(
                    matches!(
                        o.status,
                        RequestStatus::Completed | RequestStatus::Rejected | RequestStatus::TimedOut
                    ),
                    "{strategy:?}/{mix:?}: req {} in {:?}",
                    o.id,
                    o.status
                );
            }
            // Accounting identity.
            assert_eq!(
                out.metrics.n_completed + out.metrics.n_rejected + out.metrics.n_timed_out,
                150,
                "{strategy:?}/{mix:?}"
            );
        }
    }
}

#[test]
fn runs_are_bit_deterministic() {
    for strategy in [StrategyKind::FinalAdrrOlc, StrategyKind::QuotaTiered] {
        let a = run_one(strategy, Mix::Heavy, 14.0, 120, 9);
        let b = run_one(strategy, Mix::Heavy, 14.0, 120, 9);
        for (x, y) in a.outcomes.iter().zip(b.outcomes.iter()) {
            assert_eq!(x.status, y.status);
            assert_eq!(x.latency_ms, y.latency_ms);
            assert_eq!(x.defer_count, y.defer_count);
        }
        assert_eq!(a.diagnostics.sends, b.diagnostics.sends);
    }
}

#[test]
fn only_overload_strategies_shed() {
    for strategy in ALL_STRATEGIES {
        let out = run_one(strategy, Mix::Heavy, 14.0, 150, 3);
        if strategy != StrategyKind::FinalAdrrOlc {
            assert_eq!(out.metrics.rejects_total, 0, "{strategy:?} must not reject");
            assert_eq!(out.metrics.defers_total, 0, "{strategy:?} must not defer");
        }
    }
}

#[test]
fn final_stack_never_rejects_shorts_or_mediums() {
    for seed in 0..8 {
        let out = run_one(StrategyKind::FinalAdrrOlc, Mix::Heavy, 16.0, 200, seed);
        assert_eq!(out.metrics.rejects_by_bucket[TokenBucket::Short.index()], 0);
        assert_eq!(out.metrics.rejects_by_bucket[TokenBucket::Medium.index()], 0);
        assert_eq!(out.metrics.defers_by_bucket[TokenBucket::Short.index()], 0);
        assert_eq!(out.metrics.defers_by_bucket[TokenBucket::Medium.index()], 0);
    }
}

#[test]
fn zero_feasibility_violations_in_paper_regimes() {
    // The paper reports zero ordering-layer feasibility violations across
    // all runs; our main-benchmark regimes must reproduce that.
    for (mix, rate) in [(Mix::Balanced, 12.0), (Mix::Balanced, 20.0), (Mix::Heavy, 10.0), (Mix::Heavy, 14.0)]
    {
        for seed in 0..5 {
            let out = run_one(StrategyKind::FinalAdrrOlc, mix, rate, 200, seed);
            assert_eq!(
                out.metrics.feasibility_violations, 0,
                "{mix:?}@{rate}: seed {seed}"
            );
        }
    }
}

#[test]
fn shaping_beats_naive_on_short_tail_under_stress() {
    let mut wins = 0;
    for seed in 0..5 {
        let naive = run_one(StrategyKind::DirectNaive, Mix::Heavy, 14.0, 200, seed);
        let shaped = run_one(StrategyKind::FinalAdrrOlc, Mix::Heavy, 14.0, 200, seed);
        if shaped.metrics.short_p95_ms < naive.metrics.short_p95_ms {
            wins += 1;
        }
    }
    assert!(wins >= 4, "shaped won only {wins}/5 seeds");
}

#[test]
fn quota_trades_completion_for_isolation_in_heavy_regimes() {
    let mut quota_cr = 0.0;
    let mut drr_cr = 0.0;
    for seed in 0..5 {
        quota_cr += run_one(StrategyKind::QuotaTiered, Mix::Heavy, 14.0, 200, seed)
            .metrics
            .completion_rate;
        drr_cr += run_one(StrategyKind::AdaptiveDrr, Mix::Heavy, 14.0, 200, seed)
            .metrics
            .completion_rate;
    }
    assert!(
        drr_cr > quota_cr + 0.25,
        "work conservation must buy completion: drr {drr_cr} vs quota {quota_cr} (sum of 5)"
    );
}

#[test]
fn latencies_are_physical() {
    // No completion can be faster than the provider's base cost, and client
    // latency must be ≥ service time (it includes queueing).
    let out = run_one(StrategyKind::FinalAdrrOlc, Mix::Balanced, 20.0, 200, 1);
    let base = ProviderCfg::default().base_ms;
    for o in &out.outcomes {
        if let Some(lat) = o.latency_ms {
            assert!(lat > base * 0.5, "req {} latency {lat} below physical floor", o.id);
        }
    }
}

#[test]
fn realtime_serve_driver_matches_policy_semantics() {
    // The wall-clock driver (threads + channels) must run the same stack to
    // completion with the analytic prior source; 40 requests at 100× time
    // compression keeps this under a couple of wall seconds.
    use blackbox_sched::provider::pool::PoolCfg;
    use blackbox_sched::scheduler::ShardPolicy;
    blackbox_sched::serve::serve_demo(
        StrategyKind::FinalAdrrOlc,
        20.0,
        40,
        0.01,
        "",
        PoolCfg::single(ProviderCfg::default()),
        ShardPolicy::LeastInflight,
        1,
        blackbox_sched::workload::ArrivalSpec::Poisson,
    )
    .expect("serve demo failed");
}

#[test]
fn realtime_serve_driver_runs_a_sharded_fleet() {
    // Same wall-clock stack against a 2-shard heterogeneous pool with
    // weighted selection: the batched multi-endpoint path end to end.
    use blackbox_sched::provider::pool::PoolCfg;
    use blackbox_sched::scheduler::ShardPolicy;
    blackbox_sched::serve::serve_demo(
        StrategyKind::FinalAdrrOlc,
        20.0,
        40,
        0.01,
        "",
        PoolCfg::heterogeneous(ProviderCfg::default(), 2, 0.5),
        ShardPolicy::Weighted,
        1,
        blackbox_sched::workload::ArrivalSpec::Poisson,
    )
    .expect("sharded serve demo failed");
}

#[test]
fn realtime_serve_driver_multiplexes_tenants() {
    // Two independent client schedulers sharing a 2-shard fleet through one
    // provider thread: every tenant's requests must reach terminal states
    // and the demo must drain cleanly (no hung channels).
    use blackbox_sched::provider::pool::PoolCfg;
    use blackbox_sched::scheduler::ShardPolicy;
    blackbox_sched::serve::serve_demo(
        StrategyKind::FinalAdrrOlc,
        20.0,
        40,
        0.01,
        "",
        PoolCfg::split(ProviderCfg::default(), 2),
        ShardPolicy::LeastInflight,
        2,
        blackbox_sched::workload::ArrivalSpec::Session { turns: 3, think_ms: 400.0 },
    )
    .expect("multi-tenant serve demo failed");
}
