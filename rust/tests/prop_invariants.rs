//! Property-based integration tests: randomized cells through the full DES
//! driver, asserting structural invariants that must hold for *any*
//! workload, policy, and seed (proptest-lite harness; failures print a
//! replayable seed).

use blackbox_sched::core::{RequestStatus, TokenBucket};
use blackbox_sched::predictor::{InfoLevel, LadderSource, NoisySource};
use blackbox_sched::provider::ProviderCfg;
use blackbox_sched::scheduler::{SchedulerCfg, StrategyKind};
use blackbox_sched::sim::driver;
use blackbox_sched::testing::prop;
use blackbox_sched::util::rng::Rng;
use blackbox_sched::workload::{Mix, WorkloadSpec};

const STRATEGIES: [StrategyKind; 8] = StrategyKind::ALL;
const MIXES: [Mix; 4] = [Mix::Balanced, Mix::Heavy, Mix::ShareGpt, Mix::FairnessHeavy];

#[test]
fn joint_metrics_always_well_formed() {
    prop::forall(40, |g| {
        let strategy = *g.choice(&STRATEGIES);
        let mix = *g.choice(&MIXES);
        let n = g.usize_in(10, 120);
        let rate = g.f64_in(1.0, 30.0);
        let seed = g.u64();
        let info = *g.choice(&InfoLevel::ALL);
        let noise = *g.choice(&[0.0, 0.2, 0.6]);

        let requests = WorkloadSpec::new(mix, n, rate).generate(seed);
        let root = Rng::new(seed).derive("p");
        let base = LadderSource::new(info, root.derive("base"));
        let out = if noise > 0.0 {
            let mut src = NoisySource::new(base, noise, root.derive("noise"));
            driver::run(&requests, &mut src, SchedulerCfg::for_strategy(strategy), ProviderCfg::default(), seed)
        } else {
            let mut src = base;
            driver::run(&requests, &mut src, SchedulerCfg::for_strategy(strategy), ProviderCfg::default(), seed)
        };
        let m = &out.metrics;

        // Conservation.
        assert_eq!(m.n_offered, n);
        assert_eq!(m.n_completed + m.n_rejected + m.n_timed_out, n);
        // Rates bounded.
        assert!((0.0..=1.0 + 1e-9).contains(&m.completion_rate));
        assert!((0.0..=1.0 + 1e-9).contains(&m.satisfaction));
        assert!(m.satisfaction <= m.completion_rate + 1e-9, "satisfied ⊆ completed");
        // Goodput consistent with makespan.
        if m.makespan_ms > 0.0 {
            let implied = m.goodput_rps * m.makespan_ms / 1000.0;
            assert!(implied <= n as f64 + 1e-6);
        }
        // Latency positivity + deadline bookkeeping.
        for o in &out.outcomes {
            if o.status == RequestStatus::Completed {
                let lat = o.latency_ms.expect("completed has latency");
                assert!(lat > 0.0);
            } else {
                assert!(o.latency_ms.is_none());
            }
        }
        // Bucket count consistency.
        let offered: usize = m.offered_by_bucket.iter().sum();
        assert_eq!(offered, n);
        for b in 0..4 {
            assert!(m.completed_by_bucket[b] <= m.offered_by_bucket[b]);
        }
    });
}

#[test]
fn labeled_overload_never_rejects_shorts() {
    prop::forall(25, |g| {
        let mix = *g.choice(&MIXES);
        let n = g.usize_in(20, 150);
        let rate = g.f64_in(5.0, 30.0);
        let seed = g.u64();
        // Any labeled info level (no-info blind legitimately cannot protect
        // shorts it cannot see).
        let info = *g.choice(&[InfoLevel::ClassOnly, InfoLevel::Coarse, InfoLevel::Oracle]);
        let requests = WorkloadSpec::new(mix, n, rate).generate(seed);
        let mut src = LadderSource::new(info, Rng::new(seed).derive("p"));
        let out = driver::run(
            &requests,
            &mut src,
            SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc),
            ProviderCfg::default(),
            seed,
        );
        for o in &out.outcomes {
            if o.bucket == TokenBucket::Short && info != InfoLevel::Coarse {
                // class_only / oracle route by the true label: shorts are
                // never rejected. (Coarse may rarely mis-bucket a short.)
                assert_ne!(o.status, RequestStatus::Rejected, "short {} rejected", o.id);
            }
        }
    });
}

#[test]
fn tighter_budgets_never_break_conservation() {
    prop::forall(20, |g| {
        let seed = g.u64();
        let mut sched = SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc);
        sched.max_inflight = g.usize_in(1, 16);
        sched.interactive_bypass = g.usize_in(0, 8);
        let requests = WorkloadSpec::new(Mix::Heavy, 80, g.f64_in(2.0, 20.0)).generate(seed);
        let mut src = LadderSource::new(InfoLevel::Coarse, Rng::new(seed).derive("p"));
        let out = driver::run(&requests, &mut src, sched, ProviderCfg::default(), seed);
        assert_eq!(
            out.metrics.n_completed + out.metrics.n_rejected + out.metrics.n_timed_out,
            80
        );
        // The client never holds more in flight than budget + bypass.
        assert!(out.diagnostics.peak_inflight <= 16 + 8);
    });
}

#[test]
fn provider_physics_monotone_in_load() {
    // More offered load ⇒ provider-observed service can only stretch:
    // compare a lone request's latency vs the same request under heavy
    // background traffic (same seeds).
    prop::forall(15, |g| {
        let cfg = ProviderCfg::default();
        let tokens = g.f64_in(50.0, 3000.0);
        let lone = cfg.service_ms(tokens, 1);
        let crowded = cfg.service_ms(tokens, g.usize_in(2, 64));
        assert!(crowded >= lone);
    });
}
