//! Policy explorer: sweep every strategy across the paper's four regimes on
//! one seed set and print the joint-metric map — the quickest way to see the
//! regime-dependent trade-offs of §4.5.
//!
//!     cargo run --release --example policy_explorer [seeds]

use blackbox_sched::experiments::runner::{run_cell, CellSpec, Regime};
use blackbox_sched::metrics::report::{fmt_rate, TextTable};
use blackbox_sched::metrics::Aggregate;
use blackbox_sched::scheduler::{SchedulerCfg, StrategyKind};

fn main() {
    let seeds: u64 =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(3);
    let strategies = [
        StrategyKind::DirectNaive,
        StrategyKind::PacedFifo,
        StrategyKind::QuotaTiered,
        StrategyKind::ShortPriority,
        StrategyKind::FairQueuing,
        StrategyKind::PlainDrr,
        StrategyKind::AdaptiveDrr,
        StrategyKind::FinalAdrrOlc,
    ];
    for regime in Regime::GRID {
        println!("\n=== {} (rate {} req/s, {} seeds) ===", regime.name(), regime.rate_rps(), seeds);
        let mut t = TextTable::new([
            "strategy", "short P95 (ms)", "global P95 (ms)", "CR", "satisf.", "goodput",
            "defer/reject",
        ]);
        for strategy in strategies {
            let spec = CellSpec::new(regime, SchedulerCfg::for_strategy(strategy), 150);
            let runs = run_cell(&spec, seeds);
            let agg = Aggregate::new(&runs);
            let short = agg.mean_std(|m| m.short_p95_ms);
            let global = agg.mean_std(|m| m.global_p95_ms);
            let cr = agg.mean_std(|m| m.completion_rate);
            let sat = agg.mean_std(|m| m.satisfaction);
            let good = agg.mean_std(|m| m.goodput_rps);
            let defers = agg.mean_std(|m| m.defers_total as f64).0;
            let rejects = agg.mean_std(|m| m.rejects_total as f64).0;
            t.row([
                strategy.name().to_string(),
                format!("{:.0}±{:.0}", short.0, short.1),
                format!("{:.0}±{:.0}", global.0, global.1),
                fmt_rate(cr),
                fmt_rate(sat),
                format!("{:.1}±{:.1}", good.0, good.1),
                format!("{defers:.0}/{rejects:.0}"),
            ]);
        }
        println!("{}", t.render());
    }
    println!("reading guide: naive/fifo show the unshaped baseline; quota shows tail");
    println!("protection at completion cost; adaptive DRR restores completion; the");
    println!("full stack adds explicit, cost-concentrated shedding (§4.5/§4.8).");
}
