//! Overload autopsy: run one stressed heavy-dominated cell under each
//! bucket policy and dissect *who got sacrificed* — per-bucket defer and
//! reject counts, per-bucket completion, and the legibility argument of
//! §4.7 in one screen.
//!
//!     cargo run --release --example overload_autopsy

use blackbox_sched::core::TokenBucket;
use blackbox_sched::experiments::runner::{run_seed, CellSpec, Congestion, Regime};
use blackbox_sched::metrics::report::TextTable;
use blackbox_sched::scheduler::overload::BucketPolicy;
use blackbox_sched::scheduler::{SchedulerCfg, StrategyKind};
use blackbox_sched::workload::Mix;

fn main() {
    let regime = Regime { mix: Mix::Heavy, congestion: Congestion::High };
    println!("regime: {} (rate {} req/s)\n", regime.name(), regime.rate_rps());

    for policy in BucketPolicy::ALL {
        let mut sched = SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc);
        sched.overload.bucket_policy = policy;
        let spec = CellSpec::new(regime, sched, 200);
        let out = run_seed(&spec, 0);
        let m = &out.metrics;
        println!(
            "── bucket_policy = {:<14} CR {:.2}  satisfaction {:.2}  goodput {:.1} req/s",
            policy.name(),
            m.completion_rate,
            m.satisfaction,
            m.goodput_rps
        );
        let mut t = TextTable::new(["bucket", "offered", "completed", "defers", "rejects"]);
        for b in TokenBucket::ALL {
            t.row([
                b.name().to_string(),
                m.offered_by_bucket[b.index()].to_string(),
                m.completed_by_bucket[b.index()].to_string(),
                m.defers_by_bucket[b.index()].to_string(),
                m.rejects_by_bucket[b.index()].to_string(),
            ]);
        }
        println!("{}", t.render());
        assert_eq!(m.rejects_by_bucket[0], 0, "shorts must never be rejected");
    }
    println!("the cost ladder concentrates rejections on xlong and leaves medium");
    println!("untouched; uniform-mild hides overload in mass deferral; reverse");
    println!("targets the wrong bucket — explicit, objective-aligned shedding is");
    println!("what makes client-side overload *legible* (§4.7).");
}
