//! End-to-end live-serving driver (the repo's E2E validation example):
//! the identical scheduler policy code running on **wall-clock time** with a
//! provider thread, channels, and — when `make artifacts` has been run —
//! the AOT-compiled quantile-MLP predictor executed through PJRT on the
//! live admission path (L3 → runtime → L1/L2 composed).
//!
//!     make artifacts && cargo run --release --example serve_live
//!
//! Reported at the end: completion rate, deadline satisfaction, useful
//! goodput, short/global P95, and the number of PJRT predictor calls made
//! on the request path. Recorded in EXPERIMENTS.md §End-to-end.

use blackbox_sched::provider::pool::PoolCfg;
use blackbox_sched::provider::ProviderCfg;
use blackbox_sched::runtime::default_artifacts_dir;
use blackbox_sched::scheduler::{ShardPolicy, StrategyKind};

fn main() -> anyhow::Result<()> {
    let rate = 20.0; // model-time req/s
    let n = 60;
    let scale = 0.05; // 20× faster than model time
    // A 2-shard heterogeneous fleet with weighted selection: the E2E
    // example now exercises the sharded dispatch path end to end.
    let pool = PoolCfg::heterogeneous(ProviderCfg::default(), 2, 0.5);
    println!("live serve: {n} requests at {rate}/s (model time), time scale {scale}");
    blackbox_sched::serve::serve_demo(
        StrategyKind::FinalAdrrOlc,
        rate,
        n,
        scale,
        &default_artifacts_dir(),
        pool,
        ShardPolicy::Weighted,
        1,
        blackbox_sched::workload::ArrivalSpec::Poisson,
    )
}
