//! Quickstart: build a workload, run the full three-layer client scheduler
//! against the congestion-aware mock provider, and read the joint metrics.
//!
//!     cargo run --release --example quickstart

use blackbox_sched::predictor::{InfoLevel, LadderSource};
use blackbox_sched::provider::ProviderCfg;
use blackbox_sched::scheduler::{SchedulerCfg, StrategyKind};
use blackbox_sched::sim::driver;
use blackbox_sched::util::rng::Rng;
use blackbox_sched::workload::{Mix, WorkloadSpec};

fn main() {
    // 1. A balanced workload under high congestion: 200 requests at 20/s.
    let workload = WorkloadSpec::new(Mix::Balanced, 200, 20.0);
    let requests = workload.generate(/* seed */ 7);

    // 2. Coarse semi-clairvoyant priors — the paper's enabling premise.
    let mut priors = LadderSource::new(InfoLevel::Coarse, Rng::new(7).derive("priors"));

    // 3. The full stack: adaptive DRR + feasible-set ordering + overload
    //    control on the cost ladder.
    let sched = SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc);

    // 4. Run on virtual time (milliseconds of wall clock for seconds of
    //    model time).
    let out = driver::run(&requests, &mut priors, sched, ProviderCfg::default(), 7);

    let m = &out.metrics;
    println!("offered            {}", m.n_offered);
    println!("completed          {}  (rate {:.3})", m.n_completed, m.completion_rate);
    println!("deadline satisf.   {:.3}", m.satisfaction);
    println!("useful goodput     {:.2} req/s", m.goodput_rps);
    println!("short P95          {:.0} ms", m.short_p95_ms);
    println!("global P95         {:.0} ms", m.global_p95_ms);
    println!("defers / rejects   {} / {}", m.defers_total, m.rejects_total);
    println!("feasibility violations {}", m.feasibility_violations);
    assert_eq!(m.rejects_by_bucket[0], 0, "shorts are never rejected");
}
