"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes and value ranges; assert_allclose against ref.py is
the core correctness signal for the compute layer (the AOT artifact lowers
exactly these kernels).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fused_mlp import BM, D_IN, H1, H2, fused_mlp
from compile.kernels.quantile_head import OUT_PAD, quantile_head

jax.config.update("jax_platform_name", "cpu")


def _mk(rng, *shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)


def _mlp_args(seed, batch_tiles, scale=1.0):
    rng = np.random.default_rng(seed)
    x = _mk(rng, batch_tiles * BM, D_IN, scale=scale)
    w1 = _mk(rng, D_IN, H1, scale=scale)
    b1 = _mk(rng, H1, scale=scale)
    w2 = _mk(rng, H1, H2, scale=scale)
    b2 = _mk(rng, H2, scale=scale)
    return x, w1, b1, w2, b2


class TestFusedMlp:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), tiles=st.integers(1, 4))
    def test_matches_ref(self, seed, tiles):
        args = _mlp_args(seed, tiles)
        got = fused_mlp(*args)
        want = ref.fused_mlp_ref(*args)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           scale=st.sampled_from([1e-3, 1e-1, 1.0, 10.0]))
    def test_value_ranges(self, seed, scale):
        args = _mlp_args(seed, 1, scale=scale)
        got = fused_mlp(*args)
        want = ref.fused_mlp_ref(*args)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * scale)

    def test_output_shape_and_dtype(self):
        args = _mlp_args(0, 2)
        out = fused_mlp(*args)
        assert out.shape == (2 * BM, H2)
        assert out.dtype == jnp.float32

    def test_relu_nonnegative(self):
        args = _mlp_args(7, 1)
        assert float(jnp.min(fused_mlp(*args))) >= 0.0

    def test_zero_input_gives_bias_path(self):
        x, w1, b1, w2, b2 = _mlp_args(3, 1)
        x = jnp.zeros_like(x)
        got = fused_mlp(x, w1, b1, w2, b2)
        want = ref.fused_mlp_ref(x, w1, b1, w2, b2)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)

    def test_rejects_bad_batch(self):
        x, w1, b1, w2, b2 = _mlp_args(0, 1)
        with pytest.raises(ValueError, match="multiple"):
            fused_mlp(x[: BM - 1], w1, b1, w2, b2)

    def test_rejects_bad_width(self):
        x, w1, b1, w2, b2 = _mlp_args(0, 1)
        with pytest.raises(ValueError, match="feature width"):
            fused_mlp(x[:, : D_IN - 1], w1, b1, w2, b2)


def _head_args(seed, batch_tiles, scale=1.0):
    rng = np.random.default_rng(seed)
    h = jnp.abs(_mk(rng, batch_tiles * BM, H2, scale=scale))
    wq = jnp.zeros((H2, OUT_PAD), jnp.float32).at[:, :2].set(
        _mk(rng, H2, 2, scale=scale))
    bq = jnp.zeros((OUT_PAD,), jnp.float32).at[:2].set(_mk(rng, 2, scale=scale))
    return h, wq, bq


class TestQuantileHead:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), tiles=st.integers(1, 3))
    def test_matches_ref(self, seed, tiles):
        args = _head_args(seed, tiles)
        got = quantile_head(*args)
        want = ref.quantile_head_ref(*args)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_monotone_p90_ge_p50(self, seed):
        got = quantile_head(*_head_args(seed, 1))
        assert bool(jnp.all(got[:, 1] >= got[:, 0]))

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_quantiles_positive(self, seed):
        got = quantile_head(*_head_args(seed, 1))
        assert bool(jnp.all(got[:, 0] > 0.0))

    def test_pad_lanes_zero(self):
        got = quantile_head(*_head_args(11, 1))
        np.testing.assert_array_equal(np.asarray(got[:, 2:]), 0.0)

    def test_rejects_bad_batch(self):
        h, wq, bq = _head_args(0, 1)
        with pytest.raises(ValueError, match="multiple"):
            quantile_head(h[:3], wq, bq)
