"""AOT path: HLO text emission is well-formed and executable via jax's own
CPU client, and the artifact metadata carries everything the Rust side needs."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import datagen
from compile.aot import BATCH_SIZES, golden_vectors, to_hlo_text
from compile.model import init_params, predict, predict_ref

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(1), datagen.TOKEN_SCALE)


def test_hlo_text_emission(params):
    spec = jax.ShapeDtypeStruct((128, datagen.D_IN), jnp.float32)
    lowered = jax.jit(lambda x: (predict(params, x),)).lower(spec)
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[128,32]" in text  # parameter shape survives
    # Large constants must be printed verbatim: the xla_extension 0.5.1 text
    # parser zero-fills the "{...}" elision, which silently discards the
    # trained weights (the bug this test pins).
    assert "{...}" not in text
    # A ~20K-weight model serializes to hundreds of KB of text.
    assert len(text) > 100_000


def test_golden_vectors_match_ref(params):
    g = golden_vectors(params, n=8)
    feats = jnp.asarray(np.array(g["features"], dtype=np.float32))
    pred = predict_ref(params, feats)
    np.testing.assert_allclose(pred[:, 0], g["expected_p50"], rtol=1e-5)
    np.testing.assert_allclose(pred[:, 1], g["expected_p90"], rtol=1e-5)
    assert all(p90 >= p50 for p50, p90 in zip(g["expected_p50"], g["expected_p90"]))


def test_meta_dict_complete():
    meta = datagen.meta_dict()
    for key in ("buckets", "bucket_order", "tasks", "task_given_bucket",
                "prompt_alpha", "prompt_beta", "prompt_sigma", "mixes",
                "feature_layout", "token_scale", "d_in"):
        assert key in meta, f"meta missing {key}"
    assert meta["bucket_order"] == ["short", "medium", "long", "xlong"]
    assert len(meta["feature_layout"]) == 8


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__),
                                    "../../artifacts/predictor_meta.json")),
    reason="artifacts not built (run `make artifacts`)")
def test_built_artifacts_consistent():
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(root, "predictor_meta.json")) as f:
        meta = json.load(f)
    for name in meta["artifacts"]:
        path = os.path.join(root, name)
        assert os.path.exists(path), f"missing artifact {name}"
        with open(path) as fh:
            head = fh.read(4096)
        assert "HloModule" in head
    assert meta["model"]["batch_sizes"] == list(BATCH_SIZES)
    g = meta["golden"]
    assert len(g["features"]) == len(g["expected_p50"]) == len(g["expected_p90"])
    # Trained predictor should order buckets correctly on the golden set in
    # aggregate: p50 for xlong-ish rows above p50 for short-ish rows.
    p50 = np.array(g["expected_p50"])
    true = np.array(g["true_tokens"])
    if len(p50) >= 4 and true.std() > 0:
        assert np.corrcoef(p50, true)[0, 1] > 0.0
