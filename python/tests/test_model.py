"""L2 correctness: predictor model shapes, Pallas-vs-ref parity, training
behaviour, and datagen invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import datagen
from compile.kernels.fused_mlp import BM, D_IN
from compile.model import init_params, pad_batch, pinball_loss, predict, predict_ref
from compile.train import adam_init, adam_step, train

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), datagen.TOKEN_SCALE)


class TestPredict:
    def test_shapes(self, params):
        x = jnp.zeros((BM, D_IN), jnp.float32)
        out = predict(params, x)
        assert out.shape == (BM, 2)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_pallas_matches_ref(self, params, seed):
        rng = np.random.default_rng(seed)
        feats, _, _ = datagen.sample_requests(rng, BM)
        x = jnp.asarray(feats)
        np.testing.assert_allclose(
            predict(params, x), predict_ref(params, x), rtol=1e-5, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_monotone_quantiles(self, params, seed):
        rng = np.random.default_rng(seed)
        feats, _, _ = datagen.sample_requests(rng, BM)
        out = predict_ref(params, jnp.asarray(feats))
        assert bool(jnp.all(out[:, 1] >= out[:, 0]))

    def test_pad_batch(self):
        x = jnp.ones((5, D_IN))
        padded = pad_batch(x)
        assert padded.shape == (BM, D_IN)
        np.testing.assert_array_equal(np.asarray(padded[5:]), 0.0)
        assert pad_batch(jnp.ones((BM, D_IN))).shape == (BM, D_IN)


class TestTraining:
    def test_pinball_loss_positive(self, params):
        rng = np.random.default_rng(0)
        feats, ytok, _ = datagen.sample_requests(rng, 256)
        loss = pinball_loss(params, jnp.asarray(feats), jnp.asarray(ytok))
        assert float(loss) > 0.0

    def test_adam_step_moves_params(self, params):
        tp = {k: v for k, v in params.items() if k != "token_scale"}
        grads = jax.tree_util.tree_map(jnp.ones_like, tp)
        newp, _ = adam_step(tp, grads, adam_init(tp))
        assert not np.allclose(np.asarray(newp["w1"]), np.asarray(params["w1"]))

    def test_short_training_reduces_loss_and_covers(self):
        p, metrics = train(seed=3, steps=120, batch=256, verbose=False)
        # Pinball loss should be well below the untrained O(1) level and the
        # p90 head must over-cover the p50 head.
        assert metrics["final_pinball"] < 0.5
        assert metrics["coverage_p90"] > metrics["coverage_p50"]
        assert 0.25 < metrics["coverage_p50"] < 0.8
        assert metrics["coverage_p90"] > 0.6


class TestDatagen:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           mix=st.sampled_from(list(datagen.MIXES)))
    def test_samples_in_bucket_ranges(self, seed, mix):
        rng = np.random.default_rng(seed)
        feats, ytok, aux = datagen.sample_requests(rng, 512, mix)
        for i, name in enumerate(datagen.BUCKET_ORDER):
            lo, hi = datagen.BUCKETS[name]
            sel = aux["bucket_idx"] == i
            if sel.any():
                assert ytok[sel].min() >= lo and ytok[sel].max() <= hi

    def test_mix_proportions(self):
        rng = np.random.default_rng(42)
        _, _, aux = datagen.sample_requests(rng, 40000, "balanced")
        frac = np.bincount(aux["bucket_idx"], minlength=4) / 40000
        np.testing.assert_allclose(frac, datagen.MIXES["balanced"], atol=0.02)

    def test_feature_layout(self):
        f = datagen.features_from_raw([100], [2], [0.5], [1024])
        assert f.shape == (1, datagen.D_IN)
        assert f[0, 0] == pytest.approx(100 / 2048)
        assert f[0, 1] == pytest.approx(np.log1p(100) / 8)
        assert f[0, 2 + 2] == 1.0 and f[0, 2] == 0.0
        assert f[0, 6] == 0.5
        assert f[0, 7] == pytest.approx(1024 / 4096)
        np.testing.assert_array_equal(f[0, 8:], 0.0)

    def test_prompt_correlates_with_output(self):
        rng = np.random.default_rng(7)
        _, ytok, aux = datagen.sample_requests(rng, 20000)
        r = np.corrcoef(np.log(aux["prompt_tok"]), np.log(ytok))[0, 1]
        assert r > 0.3, f"prompt/output correlation too weak: {r}"

    def test_deterministic_given_seed(self):
        a = datagen.sample_requests(np.random.default_rng(5), 64)
        b = datagen.sample_requests(np.random.default_rng(5), 64)
        np.testing.assert_array_equal(a[0], b[0])
        np.testing.assert_array_equal(a[1], b[1])
