"""Build-time compile path: L2 model + L1 Pallas kernels + AOT lowering."""
