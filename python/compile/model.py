"""L2: the output-length predictor compute graph (JAX), calling L1 kernels.

``predict`` is the function that gets AOT-lowered to HLO text and executed
from the Rust admission path: features ``(B, D_IN)`` → quantile token
estimates ``(B, 2)`` = [p50, p90], with p90 ≥ p50 guaranteed by the kernel's
gap parameterization.

``predict_ref`` is the numerically identical pure-jnp twin (autodiff-friendly;
used for training and as the pytest oracle).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.fused_mlp import BM, D_IN, H1, H2, fused_mlp
from .kernels.quantile_head import OUT_PAD, quantile_head
from .kernels import ref


def init_params(key, token_scale: float):
    """He-initialized parameter pytree for the quantile MLP."""
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w1": jax.random.normal(k1, (D_IN, H1)) * jnp.sqrt(2.0 / D_IN),
        "b1": jnp.zeros((H1,)),
        "w2": jax.random.normal(k2, (H1, H2)) * jnp.sqrt(2.0 / H1),
        "b2": jnp.zeros((H2,)),
        # Head is stored pre-padded to OUT_PAD lanes; only lanes 0/1 live.
        "wq": jnp.zeros((H2, OUT_PAD)).at[:, :2].set(
            jax.random.normal(k3, (H2, 2)) * jnp.sqrt(1.0 / H2)
        ),
        "bq": jnp.zeros((OUT_PAD,)),
        "token_scale": jnp.float32(token_scale),
    }
    return {k: v.astype(jnp.float32) for k, v in p.items()}


def pad_batch(x, multiple: int = BM):
    """Zero-pad the batch dim up to a tile multiple (PJRT shapes are static)."""
    b = x.shape[0]
    pad = (-b) % multiple
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
    return x


def predict(params, x, *, interpret: bool = True):
    """Pallas-kernel predictor: features (B, D_IN) → (B, 2) token quantiles.

    ``B`` must be a multiple of the kernel batch tile ``BM`` (the AOT
    artifacts are compiled at fixed batch sizes; Rust pads and slices).
    """
    h = fused_mlp(x, params["w1"], params["b1"], params["w2"], params["b2"],
                  interpret=interpret)
    q = quantile_head(h, params["wq"], params["bq"], interpret=interpret)
    return q[:, :2] * params["token_scale"]


def predict_ref(params, x):
    """Pure-jnp twin of ``predict`` (training + test oracle)."""
    return ref.predictor_ref(params, x)


def pinball_loss(params, x, y, q_lo: float = 0.5, q_hi: float = 0.9):
    """Joint pinball (quantile) loss for the p50/p90 heads.

    ``y`` is the realized output-token count. Loss is computed in
    token_scale units so gradients are O(1).
    """
    pred = predict_ref(params, x) / params["token_scale"]
    yy = y[:, None] / params["token_scale"]
    err50 = yy[:, 0] - pred[:, 0]
    err90 = yy[:, 0] - pred[:, 1]
    l50 = jnp.maximum(q_lo * err50, (q_lo - 1.0) * err50)
    l90 = jnp.maximum(q_hi * err90, (q_hi - 1.0) * err90)
    return jnp.mean(l50 + l90)
