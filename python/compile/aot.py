"""AOT compile path: train the predictor, lower the Pallas/JAX graph to HLO
*text*, and write the runtime artifacts consumed by the Rust coordinator.

Run via ``make artifacts`` (python -m compile.aot --out-dir ../artifacts).
Python never runs after this step: the Rust binary loads
``artifacts/predictor_b{B}.hlo.txt`` through the PJRT C API.

Interchange is HLO **text**, not serialized HloModuleProto: jax ≥ 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the version the
published ``xla`` 0.1.6 crate binds) rejects; the text parser reassigns ids
and round-trips cleanly. See /opt/xla-example/README.md.

Outputs:
  predictor_b{B}.hlo.txt   — compiled predictor at fixed batch B (params baked)
  predictor_meta.json      — model dims, feature layout, generative-model
                             constants, training metrics, and golden
                             input/output vectors for the Rust runtime test
  params.npz               — trained weights (cache; delete to retrain)
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datagen
from .model import predict, predict_ref
from .train import train

BATCH_SIZES = (128, 512)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple for rust unwrap).

    CRITICAL: the default printer elides large constants as ``{...}`` and the
    xla_extension 0.5.1 text *parser silently zero-fills them* — the trained
    weights would vanish. ``print_large_constants`` keeps them verbatim;
    ``include_layout_in_shapes`` stays on so parameter layouts round-trip.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # New jaxlibs attach metadata attributes (source_end_line, …) the 0.5.1
    # parser rejects; strip metadata and backend configs from the text.
    opts.print_metadata = False
    opts.print_backend_config = False
    text = comp.as_hlo_module().to_string(opts)
    assert "{...}" not in text, "constant elision survived — loader would zero-fill weights"
    return text


def load_or_train(out_dir: str, retrain: bool, seed: int, steps: int):
    cache = os.path.join(out_dir, "params.npz")
    if os.path.exists(cache) and not retrain:
        data = np.load(cache)
        params = {k: jnp.asarray(data[k]) for k in data.files if k != "__metrics"}
        metrics = json.loads(str(data["__metrics"])) if "__metrics" in data.files else {}
        print(f"loaded cached params from {cache}")
        return params, metrics
    print(f"training predictor (seed={seed}, steps={steps}) ...")
    params, metrics = train(seed=seed, steps=steps)
    np.savez(cache, __metrics=json.dumps(metrics),
             **{k: np.asarray(v) for k, v in params.items()})
    return params, metrics


def golden_vectors(params, n: int = 8, seed: int = 1234):
    """Fixed feature vectors + reference outputs for the Rust runtime test."""
    rng = np.random.default_rng(seed)
    feats, ytok, aux = datagen.sample_requests(rng, n)
    pred = np.asarray(predict_ref(params, jnp.asarray(feats)))
    return {
        "features": np.asarray(feats).tolist(),
        "raw": {
            "prompt_tok": aux["prompt_tok"].tolist(),
            "task_idx": aux["task_idx"].tolist(),
            "temperature": aux["temperature"].tolist(),
            "max_tok": aux["max_tok"].tolist(),
        },
        "true_tokens": ytok.tolist(),
        "expected_p50": pred[:, 0].tolist(),
        "expected_p90": pred[:, 1].tolist(),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--retrain", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--steps", type=int, default=600)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    params, metrics = load_or_train(args.out_dir, args.retrain, args.seed, args.steps)

    artifact_names = []
    for b in BATCH_SIZES:
        spec = jax.ShapeDtypeStruct((b, datagen.D_IN), jnp.float32)
        lowered = jax.jit(lambda x: (predict(params, x),)).lower(spec)
        text = to_hlo_text(lowered)
        name = f"predictor_b{b}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        artifact_names.append(name)
        print(f"wrote {path} ({len(text)} chars)")

    meta = {
        "model": {"d_in": datagen.D_IN, "h1": 128, "h2": 128,
                  "batch_sizes": list(BATCH_SIZES),
                  "token_scale": float(datagen.TOKEN_SCALE)},
        "artifacts": artifact_names,
        "training": metrics,
        "datagen": datagen.meta_dict(),
        "golden": golden_vectors(params),
    }
    meta_path = os.path.join(args.out_dir, "predictor_meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=1)
    print(f"wrote {meta_path}")


if __name__ == "__main__":
    main()
