"""Build-time training loop for the output-length predictor (L2).

Trains the quantile MLP on samples from the shared generative model
(``datagen.py``) with a hand-rolled Adam (the image has no optax). Runs once
inside ``make artifacts``; never on the request path.

Training goes through ``model.predict_ref`` — the pure-jnp twin of the Pallas
path — because interpret-mode ``pallas_call`` is not differentiable in
general; pytest asserts the two paths agree to float tolerance, so the
weights transfer exactly.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import datagen
from .model import init_params, pinball_loss, predict_ref


def adam_init(params):
    zeros = lambda: jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros(), "v": zeros(), "t": jnp.int32(0)}


def adam_step(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat = jax.tree_util.tree_map(lambda m: m / (1 - b1 ** t), m)
    vhat = jax.tree_util.tree_map(lambda v: v / (1 - b2 ** t), v)
    new_params = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat
    )
    return new_params, {"m": m, "v": v, "t": t}


def _frozen(params):
    """token_scale is a constant, not a trainable."""
    return {k: v for k, v in params.items() if k != "token_scale"}


def train(seed: int = 0, steps: int = 600, batch: int = 1024,
          mix: str = "balanced", lr: float = 2e-3, verbose: bool = True):
    """Train the predictor; returns (params, metrics dict)."""
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    params = init_params(key, datagen.TOKEN_SCALE)

    # Pre-sample a large pool and iterate minibatches: keeps datagen out of
    # the step loop and the run deterministic.
    feats, ytok, _ = datagen.sample_requests(rng, steps * batch // 4, mix)
    feats = jnp.asarray(feats)
    ytok = jnp.asarray(ytok)

    loss_grad = jax.jit(jax.value_and_grad(
        lambda tp, ts, x, y: pinball_loss({**tp, "token_scale": ts}, x, y)))

    opt = adam_init(_frozen(params))
    ts = params["token_scale"]
    tp = _frozen(params)
    n = feats.shape[0]
    t0 = time.time()
    last = None
    for step in range(steps):
        lo = (step * batch) % max(1, n - batch)
        xb, yb = feats[lo:lo + batch], ytok[lo:lo + batch]
        # Pinball loss wants y as (B,); predict_ref broadcasts internally.
        loss, grads = loss_grad(tp, ts, xb, yb)
        tp, opt = adam_step(tp, grads, opt, lr=lr)
        last = float(loss)
        if verbose and (step % 100 == 0 or step == steps - 1):
            print(f"  train step {step:4d} pinball={last:.4f}")
    params = {**tp, "token_scale": ts}

    # Held-out evaluation: p50 coverage and p90 coverage on fresh samples.
    feats_te, ytok_te, aux = datagen.sample_requests(rng, 8192, mix)
    pred = np.asarray(predict_ref(params, jnp.asarray(feats_te)))
    cov50 = float(np.mean(ytok_te <= pred[:, 0]))
    cov90 = float(np.mean(ytok_te <= pred[:, 1]))
    # Bucket classification accuracy using p50 against true bucket bounds.
    bounds = np.array([datagen.BUCKETS[b][1] for b in datagen.BUCKET_ORDER[:-1]])
    pred_bucket = np.searchsorted(bounds, pred[:, 0], side="left")
    acc = float(np.mean(pred_bucket == aux["bucket_idx"]))
    metrics = {
        "final_pinball": last,
        "coverage_p50": cov50,
        "coverage_p90": cov90,
        "bucket_accuracy": acc,
        "train_seconds": time.time() - t0,
        "steps": steps,
        "batch": batch,
        "mix": mix,
        "seed": seed,
    }
    if verbose:
        print(f"  coverage: p50={cov50:.3f} p90={cov90:.3f} bucket_acc={acc:.3f}")
    return params, metrics
