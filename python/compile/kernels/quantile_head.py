"""L1 Pallas kernel: fused monotone quantile head.

Maps hidden activations ``h (B, H2)`` to per-request token-count quantiles
``(p50, p90)`` with the monotonicity constraint ``p90 >= p50`` enforced *in
the kernel*:

    z    = h @ Wq + bq                # (B, 2) raw head
    p50  = softplus(z[:, 0])
    p90  = p50 + softplus(z[:, 1])    # gap parameterization

The gap parameterization means the scheduler can never observe a crossed
quantile pair, which the Rust overload controller relies on (budgets are
computed from p90 − p50 spreads).

Output is padded to a (B, 128) tile with the two live columns in lanes 0/1 —
TPU VMEM tiles want a 128 minor dimension, and the PJRT caller slices the
lanes it needs. The head weights are stored pre-padded the same way.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fused_mlp import BM, H2

OUT_PAD = 128  # padded head width (lane 0 = p50 raw, lane 1 = gap raw)


def _quantile_head_kernel(h_ref, wq_ref, bq_ref, o_ref):
    h = h_ref[...]  # (BM, H2)
    z = jnp.dot(h, wq_ref[...], preferred_element_type=jnp.float32)
    z = z + bq_ref[...]  # (BM, OUT_PAD)
    sp = jnp.logaddexp(z, 0.0)  # softplus, numerically stable
    p50 = sp[:, 0:1]
    p90 = p50 + sp[:, 1:2]
    # Lane 0 = p50, lane 1 = p90, rest zero (keeps the tile layout dense).
    lane = jax.lax.broadcasted_iota(jnp.int32, (h.shape[0], OUT_PAD), 1)
    o_ref[...] = jnp.where(lane == 0, p50, jnp.where(lane == 1, p90, 0.0))


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantile_head(h, wq, bq, *, interpret: bool = True):
    """Fused monotone quantile head.

    Args:
      h: ``(B, H2)`` hidden activations, ``B`` a multiple of ``BM``.
      wq: ``(H2, OUT_PAD)`` head weights (columns ≥2 ignored, keep zero).
      bq: ``(OUT_PAD,)`` head bias.

    Returns:
      ``(B, OUT_PAD)`` with ``[:, 0] = p50``, ``[:, 1] = p90 ≥ p50``.
    """
    b, hdim = h.shape
    if hdim != H2:
        raise ValueError(f"hidden width {hdim} != {H2}")
    if b % BM != 0:
        raise ValueError(f"batch {b} not a multiple of tile {BM}; pad first")
    grid = (b // BM,)
    bqr = bq.reshape(1, OUT_PAD)
    return pl.pallas_call(
        _quantile_head_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, H2), lambda i: (i, 0)),
            pl.BlockSpec((H2, OUT_PAD), lambda i: (0, 0)),
            pl.BlockSpec((1, OUT_PAD), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BM, OUT_PAD), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, OUT_PAD), jnp.float32),
        interpret=interpret,
    )(h, wq, bqr)
