"""Pure-jnp reference oracle for the Pallas kernels.

Every kernel in this package has an exact mathematical twin here; pytest
(``python/tests/test_kernels.py``) asserts allclose agreement across a
hypothesis sweep of shapes and value ranges. Training (``train.py``) runs
against these reference functions — they are autodiff-friendly and
numerically identical to the kernels, so the AOT artifact (which lowers the
Pallas path) serves exactly the weights that were trained.
"""

from __future__ import annotations

import jax.numpy as jnp


def fused_mlp_ref(x, w1, b1, w2, b2):
    """Reference for ``kernels.fused_mlp.fused_mlp``."""
    h = jnp.maximum(jnp.dot(x, w1) + b1, 0.0)
    return jnp.maximum(jnp.dot(h, w2) + b2, 0.0)


def quantile_head_ref(h, wq, bq):
    """Reference for ``kernels.quantile_head.quantile_head``.

    Returns the same padded ``(B, OUT_PAD)`` layout: lane 0 = p50,
    lane 1 = p90 = p50 + softplus(gap), other lanes zero.
    """
    z = jnp.dot(h, wq) + bq
    sp = jnp.logaddexp(z, 0.0)
    p50 = sp[:, 0:1]
    p90 = p50 + sp[:, 1:2]
    out = jnp.zeros_like(z)
    out = out.at[:, 0:1].set(p50)
    out = out.at[:, 1:2].set(p90)
    return out


def predictor_ref(params, x):
    """Full reference predictor: features → (B, 2) [p50_tokens, p90_tokens].

    Mirrors ``model.predict`` but through the reference ops. ``params`` is
    the dict produced by ``train.init_params``/``train.train``.
    """
    h = fused_mlp_ref(x, params["w1"], params["b1"], params["w2"], params["b2"])
    q = quantile_head_ref(h, params["wq"], params["bq"])
    return q[:, :2] * params["token_scale"]
