"""L1 Pallas kernel: fused two-layer MLP block for the output-length predictor.

Computes ``relu(relu(x @ W1 + b1) @ W2 + b2)`` in a single kernel so the
intermediate activations never round-trip through HBM.

TPU mapping (see DESIGN.md §Hardware-Adaptation):
  * The grid iterates over batch tiles; each step stages one ``(BM, D_IN)``
    activation tile plus the full weight set into VMEM via ``BlockSpec``.
  * Weights are small (D_IN×H1 + H1×H2 ≈ 20 K f32 ≈ 80 KiB) and are mapped
    with a constant index_map, so Mosaic keeps them VMEM-resident across grid
    steps instead of re-fetching from HBM.
  * Matmul shapes are MXU-idiomatic: minor dims are 128, second-minor dims
    are multiples of 8; accumulation is forced to f32 via
    ``preferred_element_type``.

On this CPU-only image the kernel is executed with ``interpret=True`` (real
TPU lowering emits a Mosaic custom-call the CPU PJRT plugin cannot run); the
block structure is still the one a TPU build would use.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Canonical model dims (must match predictor_meta.json and rust/src/predictor).
D_IN = 32   # feature vector width (8 live features, zero-padded — lane-friendly)
H1 = 128    # first hidden width  (one MXU tile)
H2 = 128    # second hidden width (one MXU tile)

# Batch tile: 128 rows keeps the MXU systolic array fully fed while the
# activation tile (128×128 f32 = 64 KiB) plus weights stay well under the
# ~16 MiB VMEM budget. See EXPERIMENTS.md §Perf for the footprint table.
BM = 128


def _fused_mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    """One grid step: one batch tile through both layers, VMEM-resident."""
    x = x_ref[...]  # (BM, D_IN)
    h = jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
    h = jnp.maximum(h + b1_ref[...], 0.0)  # (BM, H1)
    z = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = jnp.maximum(z + b2_ref[...], 0.0)  # (BM, H2)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_mlp(x, w1, b1, w2, b2, *, interpret: bool = True):
    """Fused ``relu(relu(x@W1+b1)@W2+b2)``.

    Args:
      x: ``(B, D_IN)`` float32, ``B`` a multiple of ``BM`` (callers pad).
      w1: ``(D_IN, H1)``; b1: ``(H1,)``; w2: ``(H1, H2)``; b2: ``(H2,)``.
      interpret: run the Pallas interpreter (required on CPU PJRT).

    Returns:
      ``(B, H2)`` float32 activations.
    """
    b, d_in = x.shape
    if d_in != D_IN:
        raise ValueError(f"feature width {d_in} != {D_IN}")
    if b % BM != 0:
        raise ValueError(f"batch {b} not a multiple of tile {BM}; pad first")
    grid = (b // BM,)
    # Biases are staged as (1, H) rows: TPU VMEM wants ≥2D tiles and the
    # broadcast against the (BM, H) activation tile is free on the VPU.
    b1r = b1.reshape(1, H1)
    b2r = b2.reshape(1, H2)
    return pl.pallas_call(
        _fused_mlp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, D_IN), lambda i: (i, 0)),   # stream batch tiles
            pl.BlockSpec((D_IN, H1), lambda i: (0, 0)),   # weights: VMEM-resident
            pl.BlockSpec((1, H1), lambda i: (0, 0)),
            pl.BlockSpec((H1, H2), lambda i: (0, 0)),
            pl.BlockSpec((1, H2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BM, H2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, H2), jnp.float32),
        interpret=interpret,
    )(x, w1, b1r, w2, b2r)
