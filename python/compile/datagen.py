"""Synthetic request generative model shared between Python (predictor
training) and Rust (workload generation).

The paper's enabling premise is a production output-length predictor
(SageSched, Gan et al. 2026). We have no production prompt corpus, so we
define an explicit generative model linking *client-observable* request
features (prompt length, task type, temperature, max_tokens cap) to the
*hidden* output-token count, with irreducible noise — exactly the situation a
real predictor faces. The quantile MLP is trained on samples from this model;
the Rust workload generator (`rust/src/workload/synth.rs`) implements the
same process so the AOT predictor is evaluated in-distribution.

All constants here are exported into ``artifacts/predictor_meta.json`` by
``aot.py``; the Rust side asserts at load time that the constants it was
compiled with match the artifact (guards against drift).

Generative process (per request, given a bucket mix):
  1. bucket  ~ Categorical(mix)                    # short/medium/long/xlong
  2. out_tok ~ LogUniform(bucket_lo, bucket_hi)
  3. task    ~ Categorical(TASK_GIVEN_BUCKET[bucket])
  4. ln(prompt_tok) = PROMPT_ALPHA[task] + PROMPT_BETA[task]·ln(out_tok)
                      + N(0, PROMPT_SIGMA)          # clipped to [4, 4096]
  5. temperature ~ U(0, 1) on a 0.05 grid
  6. max_tok = smallest of {256, 512, 1024, 2048, 4096} ≥ bucket_hi

Feature layout (width D_IN = 32, lanes 8.. zero):
  f0 = prompt_tok / 2048
  f1 = log1p(prompt_tok) / 8
  f2..f5 = one-hot task type (chat, summarize, code, extract)
  f6 = temperature
  f7 = max_tok / 4096
"""

from __future__ import annotations

import numpy as np

# Token buckets (inclusive bounds), as defined in the paper §4.1/§4.2.
BUCKETS = {
    "short": (8, 64),
    "medium": (65, 256),
    "long": (257, 1024),
    "xlong": (1025, 4096),
}
BUCKET_ORDER = ["short", "medium", "long", "xlong"]

TASKS = ["chat", "summarize", "code", "extract"]

# P(task | bucket): short work skews chat/extract, xlong skews code/summarize.
TASK_GIVEN_BUCKET = {
    "short": [0.45, 0.05, 0.10, 0.40],
    "medium": [0.40, 0.20, 0.25, 0.15],
    "long": [0.25, 0.35, 0.30, 0.10],
    "xlong": [0.10, 0.40, 0.45, 0.05],
}

# ln(prompt) = alpha + beta * ln(out) + N(0, sigma): prompts are informative
# about output length but noisy (sigma=0.45 ≈ ±55% one-sigma band).
PROMPT_ALPHA = [2.2, 4.1, 1.8, 3.5]   # per task
PROMPT_BETA = [0.55, 0.35, 0.70, 0.30]
PROMPT_SIGMA = 0.45

MAX_TOKENS_GRID = [256, 512, 1024, 2048, 4096]

D_IN = 32
TOKEN_SCALE = 256.0  # head outputs tokens / TOKEN_SCALE

# Canonical workload mixes (paper §4.2 and §4.1 ShareGPT split; "<1%" → 1%).
MIXES = {
    "balanced": [0.50, 0.25, 0.15, 0.10],
    "heavy": [0.20, 0.20, 0.30, 0.30],
    "sharegpt": [0.12, 0.42, 0.45, 0.01],
}


def meta_dict() -> dict:
    """Constants bundle exported to artifacts/predictor_meta.json."""
    return {
        "d_in": D_IN,
        "token_scale": TOKEN_SCALE,
        "buckets": {k: list(v) for k, v in BUCKETS.items()},
        "bucket_order": BUCKET_ORDER,
        "tasks": TASKS,
        "task_given_bucket": TASK_GIVEN_BUCKET,
        "prompt_alpha": PROMPT_ALPHA,
        "prompt_beta": PROMPT_BETA,
        "prompt_sigma": PROMPT_SIGMA,
        "max_tokens_grid": MAX_TOKENS_GRID,
        "mixes": MIXES,
        "feature_layout": [
            "prompt_tok/2048", "log1p(prompt_tok)/8",
            "task=chat", "task=summarize", "task=code", "task=extract",
            "temperature", "max_tok/4096",
        ],
    }


def features_from_raw(prompt_tok, task_idx, temperature, max_tok) -> np.ndarray:
    """Vectorized feature computation (mirrors rust predictor/features.rs)."""
    prompt_tok = np.asarray(prompt_tok, dtype=np.float64)
    n = prompt_tok.shape[0]
    f = np.zeros((n, D_IN), dtype=np.float32)
    f[:, 0] = prompt_tok / 2048.0
    f[:, 1] = np.log1p(prompt_tok) / 8.0
    f[np.arange(n), 2 + np.asarray(task_idx)] = 1.0
    f[:, 6] = temperature
    f[:, 7] = np.asarray(max_tok, dtype=np.float64) / 4096.0
    return f


def sample_requests(rng: np.random.Generator, n: int, mix_name: str = "balanced"):
    """Sample ``n`` synthetic requests; returns (features, out_tokens, aux).

    ``aux`` is a dict of the raw fields, used by tests and by the trace
    exporter in ``aot.py --dump-train-sample``.
    """
    mix = np.asarray(MIXES[mix_name])
    bucket_idx = rng.choice(len(BUCKET_ORDER), size=n, p=mix / mix.sum())
    lo = np.array([BUCKETS[BUCKET_ORDER[i]][0] for i in bucket_idx], dtype=np.float64)
    hi = np.array([BUCKETS[BUCKET_ORDER[i]][1] for i in bucket_idx], dtype=np.float64)
    out_tok = np.exp(rng.uniform(np.log(lo), np.log(hi))).round().clip(lo, hi)

    task_idx = np.empty(n, dtype=np.int64)
    for bi, bname in enumerate(BUCKET_ORDER):
        mask = bucket_idx == bi
        if mask.any():
            task_idx[mask] = rng.choice(
                len(TASKS), size=int(mask.sum()), p=np.asarray(TASK_GIVEN_BUCKET[bname])
            )

    alpha = np.asarray(PROMPT_ALPHA)[task_idx]
    beta = np.asarray(PROMPT_BETA)[task_idx]
    ln_prompt = alpha + beta * np.log(out_tok) + rng.normal(0.0, PROMPT_SIGMA, size=n)
    prompt_tok = np.exp(ln_prompt).round().clip(4, 4096)

    temperature = np.round(rng.uniform(0.0, 1.0, size=n) * 20.0) / 20.0
    grid = np.asarray(MAX_TOKENS_GRID, dtype=np.float64)
    max_tok = np.array([grid[grid >= h][0] for h in hi])

    feats = features_from_raw(prompt_tok, task_idx, temperature, max_tok)
    aux = {
        "bucket_idx": bucket_idx,
        "task_idx": task_idx,
        "prompt_tok": prompt_tok,
        "temperature": temperature,
        "max_tok": max_tok,
    }
    return feats, out_tok.astype(np.float32), aux
